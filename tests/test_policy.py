"""DispatchPolicy: validation, profile persistence, resolution, routing, and
the policy-invariance property (policies move performance knobs, never
predictions).  Methodology reference: docs/dispatch.md."""

import json
import os
import warnings

import jax
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import CostModelConfig, GNNConfig
from repro.core.model import init_cost_model
from repro.dsps import WorkloadGenerator
from repro.placement import sample_assignment_matrix
from repro.serve import CostEstimator, PlacementService
from repro.serve.policy import (
    PROFILE_ENV,
    PROFILE_SCHEMA_VERSION,
    DispatchPolicy,
    autotune,
    host_fingerprint,
    load_profile,
    resolve_policy,
    save_profile,
    use_policy,
)

GEN = WorkloadGenerator(seed=11)


def _models(metrics=("latency_p", "success"), hidden=16, n_ensemble=2):
    models = {}
    for i, m in enumerate(metrics):
        cfg = CostModelConfig(metric=m, n_ensemble=n_ensemble, gnn=GNNConfig(hidden=hidden))
        models[m] = (init_cost_model(jax.random.PRNGKey(40 + i), cfg), cfg)
    return models


def _mixed_requests(n_structures=4, rows=4, seed=23):
    kinds = ("linear", "two_way", "three_way")
    out = []
    for i in range(n_structures):
        q = GEN.query(kind=kinds[i % len(kinds)], name=f"pol{seed}-{i}")
        c = GEN.cluster(4 + i % 3)
        a = sample_assignment_matrix(q, c, rows, np.random.default_rng(seed + i))
        out.append((q, c, a))
    return out


# -- validation / serialization ---------------------------------------------------


def test_policy_roundtrips_through_json():
    p = DispatchPolicy(cross_query_row_limit=None, score_chunk=0, double_buffer=True)
    d = json.loads(json.dumps(p.to_dict()))
    assert DispatchPolicy.from_dict(d) == p


def test_policy_validate_rejects_bad_fields():
    with pytest.raises(ValueError, match="max_batch"):
        DispatchPolicy(max_batch=0).validate()
    with pytest.raises(ValueError, match="trace_cache_size"):
        DispatchPolicy(trace_cache_size=-1).validate()
    with pytest.raises(ValueError, match="score_chunk"):
        DispatchPolicy(score_chunk=None).validate()  # None only where meaningful
    with pytest.raises(ValueError, match="double_buffer"):
        DispatchPolicy(double_buffer="yes").validate()
    with pytest.raises(ValueError, match="unknown"):
        DispatchPolicy.from_dict({"not_a_knob": 1})


# -- profile persistence ----------------------------------------------------------


def test_profile_save_load_roundtrip(tmp_path):
    path = tmp_path / "prof.json"
    tuned = DispatchPolicy(cross_query_row_limit=4, score_chunk=64)
    save_profile(path, tuned, measurements={"note": "test"})
    payload = load_profile(path)
    assert payload is not None
    assert payload["schema_version"] == PROFILE_SCHEMA_VERSION
    assert payload["policy_obj"] == tuned
    assert payload["measurements"] == {"note": "test"}
    assert payload["host_fingerprint"] == host_fingerprint()


def test_foreign_host_profile_falls_back_to_defaults(tmp_path, monkeypatch):
    """A profile stamped by another machine must be ignored (None), not
    mis-applied — resolve_policy then lands on the built-in defaults."""
    path = tmp_path / "prof.json"
    save_profile(
        path,
        DispatchPolicy(cross_query_row_limit=1),
        descriptor={"node": "other-host", "machine": "never", "cpu_count": 1,
                    "backend": "cpu", "device_count": 1},
    )
    assert load_profile(path, require_host_match=True) is None
    # but an explicit env pin skips the host check (CI containers)
    assert load_profile(path, require_host_match=False)["policy_obj"].cross_query_row_limit == 1
    monkeypatch.setenv(PROFILE_ENV, str(path))
    assert resolve_policy().cross_query_row_limit == 1


def test_corrupt_or_stale_profiles_return_none_with_warning(tmp_path):
    """Unusable profiles fall back to builtins (None) AND warn once with the
    path + reason — an operator must be able to tell a tuned host from a
    silently-defaulted one.  A missing profile is the normal un-tuned state
    and stays silent."""
    from repro.serve import DispatchProfileWarning

    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.warns(DispatchProfileWarning, match=str(bad)):
        assert load_profile(bad) is None
    stale = tmp_path / "stale.json"
    save_profile(stale, DispatchPolicy())
    payload = json.loads(stale.read_text())
    payload["schema_version"] = PROFILE_SCHEMA_VERSION + 1
    stale.write_text(json.dumps(payload))
    with pytest.warns(DispatchProfileWarning, match="schema"):
        assert load_profile(stale) is None
    invalid = tmp_path / "invalid.json"
    save_profile(invalid, DispatchPolicy())
    payload = json.loads(invalid.read_text())
    payload["policy"]["max_batch"] = -1
    invalid.write_text(json.dumps(payload))
    with pytest.warns(DispatchProfileWarning, match=str(invalid)):
        assert load_profile(invalid) is None
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # missing file: silent None
        assert load_profile(tmp_path / "missing.json") is None


def test_env_override_semantics(tmp_path, monkeypatch):
    monkeypatch.setenv(PROFILE_ENV, "default")
    assert resolve_policy() == DispatchPolicy()
    monkeypatch.setenv(PROFILE_ENV, "none")
    assert resolve_policy() == DispatchPolicy()
    monkeypatch.setenv(PROFILE_ENV, str(tmp_path / "nope.json"))
    with pytest.raises(ValueError, match="dispatch"):
        resolve_policy()  # an explicit pin must never silently degrade


# -- routing determinism ----------------------------------------------------------


def _drain_stats(est, requests):
    svc = PlacementService(est, auto_start=False)
    futs = [svc.submit_score(q, c, a) for q, c, a in requests]
    svc.start()
    answers = [f.result(timeout=120) for f in futs]
    svc.close()
    return svc.stats, answers


def test_recorded_profile_deterministically_routes_drains(tmp_path):
    """The same profile yields the same merged-vs-per-structure decision on
    every run: row_limit >= drain rows merges, a tuned row_limit below them
    pins the per-structure path."""
    models = _models(hidden=20)
    requests = _mixed_requests(rows=4)

    merge_prof = tmp_path / "merge.json"
    save_profile(merge_prof, DispatchPolicy(cross_query_row_limit=16))
    split_prof = tmp_path / "split.json"
    save_profile(split_prof, DispatchPolicy(cross_query_row_limit=2))

    merged_counts, split_counts, baseline = [], [], None
    for _ in range(2):  # determinism: identical routing on repeat runs
        pm = load_profile(merge_prof)["policy_obj"]
        stats, answers = _drain_stats(CostEstimator(models, policy=pm), requests)
        assert stats.n_cross_query == len(requests), "4 rows/structure <= 16 must merge"
        merged_counts.append(stats.n_forwards)

        ps = load_profile(split_prof)["policy_obj"]
        stats2, answers2 = _drain_stats(CostEstimator(models, policy=ps), requests)
        assert stats2.n_cross_query == 0, "4 rows/structure > 2 must split"
        split_counts.append(stats2.n_forwards)

        # routing changes dispatch only, never the numbers
        for a, b in zip(answers, answers2):
            for m in a:
                np.testing.assert_allclose(a[m], b[m], rtol=1e-5, atol=1e-6)
        if baseline is None:
            baseline = answers
        else:
            for a, b in zip(baseline, answers):
                for m in a:
                    np.testing.assert_array_equal(a[m], b[m])
    assert merged_counts[0] == merged_counts[1]
    assert split_counts[0] == split_counts[1]
    assert merged_counts[0] < split_counts[0], "merged drain must use fewer forwards"


# -- the policy-invariance property ----------------------------------------------


@settings(max_examples=6, deadline=None)
@given(
    st.sampled_from([1, 2, 8, 64, None]),  # cross_query_row_limit
    st.sampled_from([0, 2, 64, 256]),  # score_chunk
    st.integers(1, 4),  # tiny cache capacities stress eviction
)
def test_any_valid_policy_changes_only_performance(row_limit, chunk, caches):
    """ANY valid policy yields float-identical score_many/estimate_many
    results: the policy moves batching, chunking, and cache knobs — never
    the math."""
    models = _models(hidden=12)
    requests = _mixed_requests(n_structures=3, rows=5, seed=31)
    graphs = [GEN.corpus(2), GEN.corpus(3)]

    def run(policy):
        est = CostEstimator(models, policy=policy)
        with use_policy(policy):
            scores = est.score_many([(q, c, a) for q, c, a in requests])
            # the placed per-structure path exercises score_chunk directly
            q0, c0, a0 = requests[0]
            scores.append(est.score(q0, c0, a0))
            ests = est.estimate_many(graphs)
        return scores, ests

    base_scores, base_ests = run(DispatchPolicy())
    policy = DispatchPolicy(
        cross_query_row_limit=row_limit,
        score_chunk=chunk,
        max_batch=8,
        trace_cache_size=caches,
        banding_cache_size=caches,
        skeleton_cache_size=caches,
        merged_group_cache_size=caches,
    ).validate()
    got_scores, got_ests = run(policy)
    for want, have in zip(base_scores, got_scores):
        for m in want:
            np.testing.assert_array_equal(have[m], want[m], err_msg=f"score {m} {policy}")
    for want, have in zip(base_ests, got_ests):
        for m in want:
            np.testing.assert_array_equal(have[m], want[m], err_msg=f"estimate {m} {policy}")


# -- autotune ---------------------------------------------------------------------


def test_autotune_budget_zero_writes_default_profile_and_reuses(tmp_path):
    """budget_s=0: every probe is skipped (budget_exhausted recorded), the
    profile still validates, and the second call is a cached no-op."""
    out = tmp_path / "tuned.json"
    res = autotune(quick=True, budget_s=0, out=out)
    assert not res.reused_cached
    assert res.policy == DispatchPolicy()
    assert "budget_exhausted" in res.measurements
    payload = load_profile(out)
    assert payload is not None and payload["policy_obj"] == res.policy

    res2 = autotune(quick=True, budget_s=0, out=out)
    assert res2.reused_cached and res2.policy == res.policy
    # force re-probes even with a valid cache
    res3 = autotune(quick=True, budget_s=0, out=out, force=True)
    assert not res3.reused_cached


def test_autotune_cli_validate_and_expect_cached(tmp_path, capsys):
    from repro.serve.policy import main

    out = tmp_path / "cli.json"
    assert main(["--quick", "--budget-s", "0", "--out", str(out)]) == 0
    assert main(["--validate", str(out)]) == 0
    assert main(["--quick", "--budget-s", "0", "--out", str(out), "--expect-cached"]) == 0
    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    assert main(["--validate", str(bad)]) == 1
    fresh = tmp_path / "fresh.json"
    assert main(["--quick", "--budget-s", "0", "--out", str(fresh), "--expect-cached"]) == 1
    capsys.readouterr()


def test_service_explicit_args_override_policy():
    """Constructor args always beat the policy — including explicit None for
    cross_query_row_limit (always merge), which _UNSET must distinguish."""
    est = CostEstimator(_models(), policy=DispatchPolicy(cross_query_row_limit=4, max_batch=32))
    svc = PlacementService(est, auto_start=False)
    assert svc.cross_query_row_limit == 4 and svc.max_batch == 32
    svc.close()
    svc = PlacementService(est, auto_start=False, cross_query_row_limit=None, max_batch=7)
    assert svc.cross_query_row_limit is None and svc.max_batch == 7
    svc.close()


def test_optimizer_search_knobs_come_from_policy():
    models = _models(metrics=("latency_p",))
    q, c = GEN.query(name="polk"), GEN.cluster(6)
    narrow = CostEstimator(models, policy=DispatchPolicy(search_k=4)).optimize(q, c, "latency_p")
    wide = CostEstimator(models, policy=DispatchPolicy(search_k=64)).optimize(q, c, "latency_p")
    assert narrow.n_candidates <= 4 < wide.n_candidates  # pool tracks policy.search_k
    # an explicit k still beats the policy
    explicit = CostEstimator(models, policy=DispatchPolicy(search_k=4)).optimize(
        q, c, "latency_p", k=16
    )
    assert explicit.n_candidates > 4
