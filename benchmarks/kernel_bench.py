"""Fused-sweep kernel benchmark + CI gate.

Two claims, measured where each is measurable on this CPU container:

* **fused_vs_per_level** — the interpret lowering executes the actual kernel
  bodies, so per-launch cost is real there: one fused ``mp_sweep``
  interpretation of the whole banding table vs L sequential ``mp_update``
  interpretations.  The ratio is the launch-amortization the fusion buys
  (on TPU the same structure also keeps the row tile resident in VMEM across
  levels — unmeasurable here, same launch arithmetic).
* **merged_kernel_vs_jnp** — the kernel-routed merged engine on the
  jnp-oracle lowering, i.e. what serving actually runs on CPU after
  ``score_many`` lost its dense-broadcast fallback.  ``seg_gather``'s ref
  lowering IS the formerly-inline formulation, so this ratio must hold
  ~1.0: the gate is regression-only (routing must cost nothing).

Launch counts are asserted, not sampled: the harness wraps the Pallas
entry points with counters and fails if a fused forward issues anything but
ONE stage-3 launch.

Usage: PYTHONPATH=src python benchmarks/kernel_bench.py --quick \
        [--min-fused-ratio 1.2] [--baseline FILE --max-regression F]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import save_result
except ModuleNotFoundError:  # invoked as a script (scripts/ci.sh): repo root off path
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.common import save_result
from repro.core.bucketing import batch_banding, bucket_size, exact_banding, pad_batch
from repro.core.gnn import GNNConfig, _banded_plan, apply_gnn_merged, init_gnn
from repro.core.graph import SLOT_RANGES, batch_graphs, build_a_place_batch, build_graph_skeleton
from repro.dsps.generator import WorkloadGenerator
from repro.kernels import mp_sweep as sweep_pkg
from repro.kernels import mp_update as update_pkg
from repro.kernels.mp_sweep.ops import mp_sweep
from repro.kernels.mp_update.ops import mp_update
from repro.placement import sample_assignment_matrix
from repro.training.batching import dataset_from_traces


def _best_of(fn, repeats):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _sweep_case(n_traces, hidden, seed=0):
    ds = dataset_from_traces(WorkloadGenerator(seed=seed).corpus(n_traces), "latency_p")
    g = pad_batch(ds.graphs, bucket_size(ds.graphs.op_x.shape[0]))
    banding = batch_banding(g)
    levels = _banded_plan(banding, SLOT_RANGES).levels
    params = init_gnn(jax.random.PRNGKey(seed), GNNConfig(hidden=hidden))["op_upd"]
    B, N = g.op_x.shape[:2]
    h = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, N, hidden))
    args = (
        jnp.asarray(g.a_flow),
        jnp.asarray(g.op_depth),
        jnp.asarray(g.op_mask, jnp.float32),
    )
    return params, h, args, levels


def _counting(holder, key, fn):
    def wrapped(*a, **k):
        holder[key] += 1
        return fn(*a, **k)

    return wrapped


def run(n_traces: int, hidden: int, repeats: int) -> dict:
    res: dict = {"n_traces": n_traces, "hidden": hidden, "repeats": repeats}
    params, h, (a_flow, depth, mask), levels = _sweep_case(n_traces, hidden)
    res["levels"] = len(levels)

    # --- launch counting + fused-vs-per-level, on the interpret lowering ---
    prev = os.environ.get("REPRO_PALLAS_INTERPRET")
    os.environ["REPRO_PALLAS_INTERPRET"] = "1"
    counts = {"sweep": 0, "update": 0}
    orig_sweep = sweep_pkg.ops.mp_sweep_pallas
    orig_update = update_pkg.ops.mp_update_pallas
    sweep_pkg.ops.mp_sweep_pallas = _counting(counts, "sweep", orig_sweep)
    update_pkg.ops.mp_update_pallas = _counting(counts, "update", orig_update)
    try:

        def fused():
            return mp_sweep(params, h, a_flow, depth, mask, levels)

        def per_level():
            out = h
            for d, span, ranges, p in levels:
                out = mp_update(
                    params, out, a_flow, depth, mask, jnp.asarray(d, depth.dtype),
                    ranges, row_span=span, parent_rows=p,
                )
            return out

        err = float(jnp.abs(fused() - per_level()).max())
        res["maxerr_fused_vs_per_level"] = err
        res["fused_launches_per_forward"] = counts["sweep"]  # must be 1
        res["per_level_launches_per_forward"] = counts["update"]  # == levels
        # the counted parity call above already warmed both paths
        t_fused = _best_of(fused, repeats)
        t_loop = _best_of(per_level, repeats)
        res["fused_us"] = t_fused * 1e6
        res["per_level_us"] = t_loop * 1e6
        res["fused_vs_per_level"] = t_loop / t_fused
    finally:
        sweep_pkg.ops.mp_sweep_pallas = orig_sweep
        update_pkg.ops.mp_update_pallas = orig_update
        if prev is None:
            os.environ.pop("REPRO_PALLAS_INTERPRET", None)
        else:
            os.environ["REPRO_PALLAS_INTERPRET"] = prev

    # --- merged engine routing cost, on the serving (jnp-oracle) lowering ---
    gen = WorkloadGenerator(seed=7)
    cluster = gen.cluster(4)
    queries = [gen.query(kind=k, name=f"b{i}") for i, k in enumerate(("linear", "two_way"))]
    rng = np.random.default_rng(7)
    skels = batch_graphs([build_graph_skeleton(q, cluster) for q in queries])
    blocks, ids = [], []
    per_q = max(8, n_traces)
    for i, q in enumerate(queries):
        a = sample_assignment_matrix(q, cluster, per_q, rng, max_tries_factor=400)
        blocks.append(build_a_place_batch(q, cluster, a))
        ids.append(np.full(len(a), i, dtype=np.int32))
    banding = exact_banding(skels)
    max_parents = int(np.asarray(skels.a_flow).sum(axis=-2).max(initial=1))
    skels_j = jax.tree_util.tree_map(jnp.asarray, skels)
    skel_id = jnp.asarray(np.concatenate(ids))
    a_place = jnp.asarray(np.concatenate(blocks))
    cfg_j = GNNConfig(hidden=hidden)
    cfg_p = GNNConfig(hidden=hidden, use_pallas=True)
    stack = jax.tree_util.tree_map(
        lambda p: p[None], init_gnn(jax.random.PRNGKey(3), cfg_j)
    )

    def merged(cfg):
        return jax.jit(
            lambda p, sid, ap: apply_gnn_merged(
                p, skels_j, sid, ap, cfg, banding, max_parents
            )
        )

    f_j, f_p = merged(cfg_j), merged(cfg_p)
    err = float(jnp.abs(f_j(stack, skel_id, a_place) - f_p(stack, skel_id, a_place)).max())
    res["maxerr_merged"] = err
    t_j = _best_of(lambda: f_j(stack, skel_id, a_place), repeats)
    t_p = _best_of(lambda: f_p(stack, skel_id, a_place), repeats)
    res["merged_jnp_us"] = t_j * 1e6
    res["merged_kernel_us"] = t_p * 1e6
    res["merged_kernel_vs_jnp"] = t_j / t_p
    res["merged_rows"] = int(a_place.shape[0])
    return res


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--traces", type=int, default=48)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--quick", action="store_true", help="small run for per-PR CI")
    ap.add_argument(
        "--min-fused-ratio",
        type=float,
        default=None,
        help="fail if fused_vs_per_level (interpret lowering) is below this",
    )
    ap.add_argument(
        "--baseline",
        type=str,
        default=None,
        help="JSON with recorded fused_vs_per_level / merged_kernel_vs_jnp ratios",
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.10,
        help="allowed fractional drop of a measured ratio below the baseline",
    )
    args = ap.parse_args(argv)
    if args.quick:
        args.traces, args.hidden, args.repeats = 24, 32, 3

    res = run(args.traces, args.hidden, args.repeats)
    print(json.dumps(res, indent=2))
    save_result("kernel_bench", res)
    # not assert: CI-gate invariants, they must survive python -O
    if res["fused_launches_per_forward"] != 1:
        raise SystemExit(
            "fused sweep must be ONE stage-3 launch per forward, got "
            f"{res['fused_launches_per_forward']}"
        )
    if res["per_level_launches_per_forward"] != res["levels"]:
        raise SystemExit("per-level path launch count does not match the banding table")
    for key in ("maxerr_fused_vs_per_level", "maxerr_merged"):
        if res[key] > 1e-4:
            raise SystemExit(f"parity violation: {key}={res[key]}")
    if args.min_fused_ratio is not None and res["fused_vs_per_level"] < args.min_fused_ratio:
        raise SystemExit(
            f"fused sweep only {res['fused_vs_per_level']:.2f}x over per-level "
            f"launches, required {args.min_fused_ratio}x"
        )
    if args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
        for key in ("fused_vs_per_level", "merged_kernel_vs_jnp"):
            floor = base[key] * (1.0 - args.max_regression)
            if res[key] < floor:
                raise SystemExit(
                    f"{key} ratio {res[key]:.3f} regressed >"
                    f"{args.max_regression:.0%} below recorded baseline "
                    f"{base[key]} (floor {floor:.3f})"
                )
    return res


if __name__ == "__main__":
    main()
