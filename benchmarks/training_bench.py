"""Training-step microbenchmark: steps/s and examples/s, seed vs unified.

Compares two implementations of one jitted training step (forward + backward
+ adam update) for a COSTREAM ensemble on identical data and weights:

  seed path     the pre-engine forward, replicated verbatim below: one
                per-member vmap of a per-graph vmap of a single-graph
                forward whose stage-3 sweep always scans all MAX_DEPTH
                levels at full row width;
  unified path  ``ensemble_loss`` on the unified engine
                (docs/forward_engine.md): banked MLPs run once across the
                whole padded batch, members ride one stacked forward, and
                the stage-3 sweep runs only the bucket's non-empty depth
                levels at their static ``row_span``/``parent_rows`` bands
                (``bucket_dataset``'s depth-major batches).

The unified path is additionally timed with **signature-exact row-trimmed
banding** (``bucket_dataset(exact=True)``): one bucket per distinct per-row
(type, depth) signature, stage-3 spans exact for that signature and padded
rows statically trimmed — strictly less stage-3 row work per step (asserted)
at the cost of one trace per signature.  Steps/s is the cross-mode
comparable quantity (identical batch shapes, less work per step).

Both steps are timed at the steady state (first call — the trace — excluded)
on the same bucketed batches, so the ratios isolate the engine restructure.
Untrained weights are fine: step time does not depend on the weights' values.

    PYTHONPATH=src python benchmarks/training_bench.py [--quick]
        [--min-speedup X]                      # unified vs seed steps/s floor
        [--min-exact-ratio X]                  # exact vs conservative steps/s floor
        [--baseline FILE --max-regression F]   # ratio gate vs recorded run
"""

from __future__ import annotations

import argparse
import json
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.core import CostModelConfig, GNNConfig, init_cost_model
from repro.core.graph import SLOT_RANGES
from repro.core.model import ensemble_loss, loss_fn
from repro.dsps import WorkloadGenerator
from repro.training import bucket_dataset, bucketed_batches, dataset_from_traces
from repro.training import optim


def _seed_apply_gnn(p, g, cfg: GNNConfig):
    """The seed-era single-graph forward (pre-unified-engine), kept verbatim
    as the benchmark baseline: full-width banked MLPs + a lax.scan over all
    ``max_depth`` levels regardless of the query's true depth."""
    op_mask = g.op_mask[:, None]
    hw_mask = g.hw_mask[:, None]
    h_ops = nn.apply_mlp_bank_slotted(p["op_enc"], g.op_x, SLOT_RANGES) * op_mask
    h_hw = nn.apply_mlp(p["hw_enc"], g.hw_x) * hw_mask
    msg_hw = g.a_place.T @ h_ops
    h_hw = nn.apply_mlp(p["hw_upd"], jnp.concatenate([h_hw, msg_hw], axis=-1)) * hw_mask
    msg_ops = g.a_place @ h_hw
    h_ops = (
        nn.apply_mlp_bank_slotted(
            p["op_upd"], jnp.concatenate([h_ops, msg_ops], axis=-1), SLOT_RANGES
        )
        * op_mask
    )

    def depth_step(h, d):
        msg = g.a_flow.T @ h
        upd = nn.apply_mlp_bank_slotted(
            p["op_upd"], jnp.concatenate([h, msg], axis=-1), SLOT_RANGES
        )
        sel = ((g.op_depth == d) & (g.op_mask > 0))[:, None]
        return jnp.where(sel, upd, h), None

    h_ops, _ = jax.lax.scan(
        depth_step, h_ops, jnp.arange(1, cfg.max_depth + 1, dtype=g.op_depth.dtype)
    )
    pooled = jnp.sum(h_ops * op_mask, axis=0) + jnp.sum(h_hw * hw_mask, axis=0)
    return nn.apply_mlp(p["out"], pooled)


def _make_steps(cfg: CostModelConfig, train_lr=1e-3):
    opt = optim.adam(lr=optim.constant_schedule(train_lr))

    def seed_loss(p, g, y):
        raw = jax.vmap(
            lambda pp: jax.vmap(lambda gg: _seed_apply_gnn(pp, gg, cfg.gnn))(g)[..., 0]
        )(p)
        return jnp.sum(jax.vmap(lambda r: loss_fn(cfg)(r, y))(raw))

    @jax.jit
    def seed_step(params, opt_state, g, y):
        loss_val, grads = jax.value_and_grad(lambda p: seed_loss(p, g, y))(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return optim.apply_updates(params, updates), opt_state, loss_val

    @partial(jax.jit, static_argnums=(4,))
    def unified_step(params, opt_state, g, y, banding):
        loss_val, grads = jax.value_and_grad(
            lambda p: ensemble_loss(p, g, y, cfg, banding)
        )(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return optim.apply_updates(params, updates), opt_state, loss_val

    return opt, seed_step, unified_step


def _stage3_rows_per_step(batches) -> float:
    """Mean padded row-work of one step's stage-3 sweep: the sum of the
    banding's level span widths (the rows whose aggregation + banked-MLP
    update actually execute; everything else is statically skipped)."""
    return float(
        np.mean(
            [sum(stop - start for _, (start, stop), _ in b.levels) for _, _, b in batches]
        )
    )


def run(n_traces: int, batch_size: int, repeats: int, seed: int = 0) -> dict:
    traces = WorkloadGenerator(seed=seed).corpus(n_traces)
    ds = dataset_from_traces(traces, "latency_p")
    ds_cons, buckets = bucket_dataset(ds)
    # signature-exact row-trimmed bands: one trace per distinct query
    # signature, stage-3 spans exact for that signature (launch/train.py's
    # default for its large fixed corpora)
    ds_exact, buckets_exact = bucket_dataset(ds, exact=True)
    cfg = CostModelConfig(metric="latency_p", n_ensemble=3, gnn=GNNConfig())
    params = init_cost_model(jax.random.PRNGKey(0), cfg)
    opt, seed_step, unified_step = _make_steps(cfg)

    def materialize(dds, bbuckets):
        return [
            (jax.tree_util.tree_map(jnp.asarray, g), jnp.asarray(y), banding)
            for g, y, banding in bucketed_batches(dds, bbuckets, batch_size)
        ]

    batches = materialize(ds_cons, buckets)
    batches_exact = materialize(ds_exact, buckets_exact)
    assert batches and batches_exact, "corpus produced no batches"

    # sanity: identical loss on the first batch before trusting the timings
    g0, y0, band0 = batches[0]
    st = opt.init(params)
    _, _, l_seed = seed_step(params, st, g0, y0)
    _, _, l_uni = unified_step(params, st, g0, y0, band0)
    np.testing.assert_allclose(float(l_seed), float(l_uni), rtol=1e-4)

    def time_epochs(step, bb, with_banding: bool):
        # warmup epoch = compile every bucket's trace; then timed epochs
        def epoch():
            p, s = params, opt.init(params)
            for g, y, banding in bb:
                p, s, _ = step(p, s, g, y, banding) if with_banding else step(p, s, g, y)
            jax.block_until_ready(p)

        epoch()
        t0 = time.perf_counter()
        for _ in range(repeats):
            epoch()
        return (time.perf_counter() - t0) / repeats

    t_seed = time_epochs(seed_step, batches, with_banding=False)
    t_uni = time_epochs(unified_step, batches, with_banding=True)
    t_exact = time_epochs(unified_step, batches_exact, with_banding=True)
    steps, steps_exact = len(batches), len(batches_exact)
    examples = steps * batch_size
    # steps/s is the comparable per-step quantity: both modes step identical
    # (batch_size, MAX_OPS-or-trimmed) shapes, exact mode just does less of
    # the stage work per step (small corpora pay more per-signature epoch
    # tails, so epoch examples/s is NOT comparable across modes)
    rate_uni = steps / t_uni
    rate_exact = steps_exact / t_exact
    return {
        "n_traces": n_traces,
        "batch_size": batch_size,
        "repeats": repeats,
        "steps_per_epoch": steps,
        "exact_steps_per_epoch": steps_exact,
        "n_buckets": len(buckets),
        "n_signature_buckets": len(buckets_exact),
        "seed_steps_per_s": round(steps / t_seed, 2),
        "unified_steps_per_s": round(rate_uni, 2),
        "exact_steps_per_s": round(rate_exact, 2),
        "seed_examples_per_s": round(examples / t_seed, 1),
        "unified_examples_per_s": round(examples / t_uni, 1),
        "unified_vs_seed": round(t_seed / t_uni, 3),
        "exact_vs_seed": round(rate_exact / (steps / t_seed), 3),
        "exact_vs_unified": round(rate_exact / rate_uni, 3),
        "unified_stage3_rows_per_step": round(_stage3_rows_per_step(batches), 2),
        "exact_stage3_rows_per_step": round(_stage3_rows_per_step(batches_exact), 2),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--traces", type=int, default=2048)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--quick", action="store_true", help="small run for per-PR CI")
    ap.add_argument("--min-speedup", type=float, default=None, help="fail below this")
    ap.add_argument(
        "--min-exact-ratio",
        type=float,
        default=None,
        help="fail if exact-banding steps/s drops below this fraction of the "
        "bucket-conservative rate (1.0 = 'no slower')",
    )
    ap.add_argument(
        "--baseline",
        type=str,
        default=None,
        help="JSON with a recorded unified_vs_seed ratio",
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.10,
        help="allowed fractional drop of the measured ratio below the baseline",
    )
    args = ap.parse_args(argv)
    if args.quick:
        args.traces, args.repeats = 768, 2

    res = run(args.traces, args.batch_size, args.repeats)
    print(json.dumps(res, indent=2))
    # not assert: these are the CI gate's invariants, they must survive python -O
    if res["exact_stage3_rows_per_step"] >= res["unified_stage3_rows_per_step"]:
        raise SystemExit(
            "signature-exact banding must do strictly less stage-3 row work "
            f"per step, got {res['exact_stage3_rows_per_step']} vs "
            f"{res['unified_stage3_rows_per_step']} (bucket-conservative)"
        )
    if args.min_exact_ratio is not None and res["exact_vs_unified"] < args.min_exact_ratio:
        raise SystemExit(
            f"exact-banding step rate is {res['exact_vs_unified']}x the "
            f"bucket-conservative rate, below required {args.min_exact_ratio}x"
        )
    if args.min_speedup is not None and res["unified_vs_seed"] < args.min_speedup:
        raise SystemExit(
            f"unified training step {res['unified_vs_seed']}x below required "
            f"{args.min_speedup}x over the seed path"
        )
    if args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
        floor = base["unified_vs_seed"] * (1.0 - args.max_regression)
        if res["unified_vs_seed"] < floor:
            raise SystemExit(
                f"unified_vs_seed ratio {res['unified_vs_seed']} regressed >"
                f"{args.max_regression:.0%} below recorded baseline "
                f"{base['unified_vs_seed']} (floor {floor:.3f})"
            )


if __name__ == "__main__":
    main()
