"""[Exp 3-6] Generalization experiments.

Exp 3 (Table IV): interpolation — unseen-but-in-range hardware values.
Exp 4 (Table V):  extrapolation — models trained on restricted hardware
                  ranges, evaluated beyond them (stronger and weaker).
Exp 5 (Table VIa + Fig 11): unseen filter-chain query patterns + fine-tuning.
Exp 6 (Table VIb): unseen real-world benchmark queries.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import eval_costream, eval_flat, fmt_table, save_result
from repro.core import ALL_METRICS, REGRESSION_METRICS
from repro.dsps import ranges
from repro.dsps.generator import GeneratorConfig, Trace, WorkloadGenerator
from repro.dsps.simulator import simulate
from repro.dsps.benchmarks import sample_benchmark_query
from repro.launch.train import CORPUS_SEED, chain_corpus, extrap_generator


def _rows_for(cs: Dict, fv: Dict) -> List[Dict]:
    rows = []
    for m in ALL_METRICS:
        if m in REGRESSION_METRICS:
            rows.append(
                {
                    "metric": m,
                    "costream_q50": round(cs[m].get("q50", float("nan")), 2),
                    "costream_q95": round(cs[m].get("q95", float("nan")), 2),
                    "flat_q50": round(fv[m].get("q50", float("nan")), 2) if fv else "",
                    "flat_q95": round(fv[m].get("q95", float("nan")), 2) if fv else "",
                }
            )
        else:
            rows.append(
                {
                    "metric": m,
                    "costream_q50": f"{100 * cs[m].get('accuracy', float('nan')):.1f}%",
                    "flat_q50": f"{100 * fv[m].get('accuracy', float('nan')):.1f}%" if fv else "",
                }
            )
    return rows


def exp3_interpolation(n: int = 400):
    interp = ranges.interpolation_ranges()
    cfg = GeneratorConfig().with_hardware(
        cpu=tuple(interp["CPU"]),
        ram_mb=tuple(interp["RAM_MB"]),
        bandwidth_mbps=tuple(interp["BANDWIDTH_MBPS"]),
        latency_ms=tuple(interp["LATENCY_MS"]),
    )
    gen = WorkloadGenerator(cfg, seed=CORPUS_SEED + 100)
    traces = gen.corpus(n, name_prefix="interp")
    cs = eval_costream(traces)
    fv = eval_flat(traces)
    rows = _rows_for(cs, fv)
    print(f"\n[Exp 3 / Table IV] interpolation: unseen in-range hardware (n={n})")
    print(fmt_table(rows, ["metric", "costream_q50", "costream_q95", "flat_q50", "flat_q95"]))
    save_result("exp3_tableIV", rows)
    return rows


def exp4_extrapolation(n: int = 250):
    spec = ranges.extrapolation_ranges()
    mapping = {
        "ram": ("ram_mb", "RAM_MB"),
        "cpu": ("cpu", "CPU"),
        "bandwidth": ("bandwidth_mbps", "BANDWIDTH_MBPS"),
        "latency": ("latency_ms", "LATENCY_MS"),
    }
    all_rows = {}
    for direction in ("stronger", "weaker"):
        rows = []
        for dim, (field, key) in mapping.items():
            # eval corpus: the restricted dim drawn from OUT-OF-RANGE values,
            # the other dims from the restricted training ranges
            gen_cfg = extrap_generator(direction, dim).with_hardware(
                **{field: tuple(spec[direction]["eval"][key])}
            )
            gen = WorkloadGenerator(gen_cfg, seed=CORPUS_SEED + 200 + hash((direction, dim)) % 97)
            traces = gen.corpus(n, name_prefix=f"x{dim}")
            cs = eval_costream(traces, prefix=f"extrap_{direction}_{dim}")
            row = {"dim": dim}
            for m in ALL_METRICS:
                if m in REGRESSION_METRICS:
                    row[f"{m}_q50"] = round(cs[m].get("q50", float("nan")), 2)
                else:
                    row[f"{m}_acc"] = f"{100 * cs[m].get('accuracy', float('nan')):.1f}%"
            rows.append(row)
        all_rows[direction] = rows
        print(f"\n[Exp 4 / Table V] extrapolation towards {direction} resources (n={n})")
        cols = ["dim"] + [
            f"{m}_q50" if m in REGRESSION_METRICS else f"{m}_acc" for m in ALL_METRICS
        ]
        print(fmt_table(rows, cols))
    save_result("exp4_tableV", all_rows)
    return all_rows


def exp5_unseen_patterns(n: int = 250):
    rows = []
    for ln in (2, 3, 4):
        traces = chain_corpus(f"eval_chain_{ln}", n, CORPUS_SEED + 300 + ln, chain_lengths=(ln,))
        cs = eval_costream(traces)
        fv = eval_flat(traces)
        rows.append(
            {
                "pattern": f"{ln}-filter-chain",
                "T_q50_cs": round(cs["throughput"].get("q50", float("nan")), 2),
                "T_q50_flat": round(fv["throughput"].get("q50", float("nan")), 2),
                "Le_q50_cs": round(cs["latency_e"].get("q50", float("nan")), 2),
                "Le_q50_flat": round(fv["latency_e"].get("q50", float("nan")), 2),
                "S_acc_cs": f"{100 * cs['success'].get('accuracy', float('nan')):.0f}%",
                "S_acc_flat": f"{100 * fv['success'].get('accuracy', float('nan')):.0f}%",
            }
        )
    print(f"\n[Exp 5a / Table VIa] unseen filter-chain patterns (n={n} each)")
    print(
        fmt_table(
            rows,
            ["pattern", "T_q50_cs", "T_q50_flat", "Le_q50_cs", "Le_q50_flat", "S_acc_cs", "S_acc_flat"],
        )
    )
    save_result("exp5a_tableVIa", rows)

    # Fig 11: fine-tuned throughput model
    rows_ft = []
    for ln in (2, 3, 4):
        traces = chain_corpus(f"eval_chain_{ln}", n, CORPUS_SEED + 300 + ln, chain_lengths=(ln,))
        before = eval_costream(traces, metrics=("throughput",))
        after = eval_costream(traces, metrics=("throughput",), prefix="finetune")
        rows_ft.append(
            {
                "pattern": f"{ln}-filter-chain",
                "before_q50": round(before["throughput"].get("q50", float("nan")), 2),
                "after_q50": round(after["throughput"].get("q50", float("nan")), 2),
            }
        )
    print("\n[Exp 5b / Fig 11] throughput q50 before/after fine-tuning")
    print(fmt_table(rows_ft, ["pattern", "before_q50", "after_q50"]))
    save_result("exp5b_fig11", rows_ft)
    return rows, rows_ft


def exp6_unseen_benchmarks(n: int = 100):
    gen = WorkloadGenerator(seed=CORPUS_SEED + 400)
    rng = np.random.default_rng(CORPUS_SEED + 401)
    rows = []
    for name in ("advertisement", "spike_detection", "smart_grid_global", "smart_grid_local"):
        traces = []
        for i in range(n):
            q = sample_benchmark_query(name, rng)
            c = gen.cluster()
            p = gen.placement(q, c)
            traces.append(Trace(query=q, cluster=c, placement=p, labels=simulate(q, c, p, rng=gen.rng)))
        cs = eval_costream(traces)
        fv = eval_flat(traces)
        rows.append(
            {
                "benchmark": name,
                "T_q50_cs": round(cs["throughput"].get("q50", float("nan")), 2),
                "T_q50_flat": round(fv["throughput"].get("q50", float("nan")), 2),
                "Lp_q50_cs": round(cs["latency_p"].get("q50", float("nan")), 2),
                "Lp_q50_flat": round(fv["latency_p"].get("q50", float("nan")), 2),
                "Ro_acc_cs": f"{100 * cs['backpressure'].get('accuracy', float('nan')):.0f}%",
                "S_acc_cs": f"{100 * cs['success'].get('accuracy', float('nan')):.0f}%",
            }
        )
    print(f"\n[Exp 6 / Table VIb] unseen real-world benchmarks (n={n} each)")
    print(
        fmt_table(
            rows,
            ["benchmark", "T_q50_cs", "T_q50_flat", "Lp_q50_cs", "Lp_q50_flat", "Ro_acc_cs", "S_acc_cs"],
        )
    )
    save_result("exp6_tableVIb", rows)
    return rows


def main():
    exp3_interpolation()
    exp4_extrapolation()
    exp5_unseen_patterns()
    exp6_unseen_benchmarks()


if __name__ == "__main__":
    main()
