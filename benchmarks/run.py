"""Benchmark entry point: one function per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--only exp1,exp2,...]

Prints each table and a final ``name,us_per_call,derived`` CSV summary; all
payloads are also saved under artifacts/results/*.json.
"""

from __future__ import annotations

import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all")
    args = ap.parse_args()
    only = args.only.split(",") if args.only != "all" else None

    from benchmarks import chaos_bench, controller_bench, exp1_accuracy, exp2_placement
    from benchmarks import exp3456, exp7_ablations, kernel_bench, kernels_bench
    from benchmarks import load_harness, placement_bench, roofline_report, serve_bench
    from benchmarks import training_bench

    stages = {
        "exp1": exp1_accuracy.main,
        "exp2": exp2_placement.main,
        "placement_search": lambda: placement_bench.main(["--quick"]),
        "training_engine": lambda: training_bench.main(["--quick"]),
        "serving": lambda: serve_bench.main(["--quick"]),
        "load_harness": lambda: load_harness.main(["--quick"]),
        "controller": lambda: controller_bench.main(["--quick"]),
        "chaos": lambda: chaos_bench.main(["--quick"]),
        "exp3": exp3456.exp3_interpolation,
        "exp4": exp3456.exp4_extrapolation,
        "exp5": exp3456.exp5_unseen_patterns,
        "exp6": exp3456.exp6_unseen_benchmarks,
        "exp7": exp7_ablations.main,
        # renamed from "kernels": this is the per-op microbenchmark lane, as
        # opposed to "kernel_sweep" (the fused sweep kernel's gated bench)
        "kernels_micro": kernels_bench.main,
        "kernel_sweep": lambda: kernel_bench.main(["--quick"]),
        "roofline": lambda: (roofline_report.main("single"), roofline_report.main("multi")),
    }
    timings = []
    failures = []
    for name, fn in stages.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            fn()
            timings.append((name, time.time() - t0, "ok"))
        except Exception as e:
            traceback.print_exc()
            timings.append((name, time.time() - t0, f"FAIL:{type(e).__name__}"))
            failures.append(name)

    print("\nname,us_per_call,derived")
    for name, secs, status in timings:
        print(f"{name},{secs * 1e6:.0f},{status}")
    if failures:
        raise SystemExit(f"failed stages: {failures}")


if __name__ == "__main__":
    main()
