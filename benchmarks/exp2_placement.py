"""[Exp 2] Placement optimization (paper Fig. 9 + Fig. 10).

2a: for each query type, optimize 50 queries' initial placements with
COSTREAM and with the flat-vector baseline; report median speed-up of
simulator-measured L_p over the heuristic initial placement [32].

2b: the online-monitoring rescheduler [1]: initial slow-down factor vs. the
COSTREAM placement and the monitoring overhead until competitive.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import FlatRanker, fmt_table, save_result, serving_estimator
from repro.dsps import WorkloadGenerator, simulate
from repro.dsps.simulator import SimulatorConfig
from repro.placement import (
    PlacementOptimizer,
    heuristic_placement,
    online_monitoring_run,
    sample_assignment_matrix,
)

SIM = SimulatorConfig(noise_sigma=0.0)  # placement quality measured noise-free


def exp2a(n_queries: int = 50, k: int = 48, seed: int = 1234):
    opt = PlacementOptimizer(serving_estimator())
    flat = FlatRanker()
    gen = WorkloadGenerator(seed=seed)
    rng = np.random.default_rng(seed)
    rows = []
    for kind in ("linear", "two_way", "three_way"):
        speed_cs, speed_fv = [], []
        for i in range(n_queries):
            q = gen.query(kind=kind, name=f"{kind}{i}")
            c = gen.cluster(6)
            base = heuristic_placement(q, c)
            base_lat = simulate(q, c, base, SIM).latency_p

            res = opt.optimize(q, c, "latency_p", k=k, rng=rng)
            cs_lat = simulate(q, c, res.placement, SIM).latency_p
            speed_cs.append(base_lat / max(cs_lat, 1e-9))

            cands = sample_assignment_matrix(q, c, k, rng)
            if len(cands) and flat.models:
                fv_p = flat.pick(q, c, cands)
                fv_lat = simulate(q, c, fv_p, SIM).latency_p
                speed_fv.append(base_lat / max(fv_lat, 1e-9))
        rows.append(
            {
                "type": kind,
                "n": n_queries,
                "costream_median_speedup": round(float(np.median(speed_cs)), 2),
                "costream_p90_speedup": round(float(np.percentile(speed_cs, 90)), 2),
                "flat_median_speedup": round(float(np.median(speed_fv)), 2) if speed_fv else "n/a",
            }
        )
    print("\n[Exp 2a / Fig 9] initial-placement speedups over heuristic [32]")
    print(
        fmt_table(
            rows,
            ["type", "n", "costream_median_speedup", "costream_p90_speedup", "flat_median_speedup"],
        )
    )
    save_result("exp2a_fig9", rows)
    return rows


def exp2b(n_queries: int = 25, seed: int = 4321):
    opt = PlacementOptimizer(serving_estimator())
    gen = WorkloadGenerator(seed=seed)
    rng = np.random.default_rng(seed)
    slowdowns, overheads = [], []
    for i in range(n_queries):
        q = gen.query(kind="linear", name=f"mon{i}")
        c = gen.cluster(6)
        res = opt.optimize(q, c, "latency_p", k=48, rng=rng)
        target = simulate(q, c, res.placement, SIM).latency_p
        init = heuristic_placement(q, c)
        mon = online_monitoring_run(q, c, init, target_latency=target, sim=SIM)
        slowdowns.append(mon.initial_latency / max(target, 1e-9))
        if np.isfinite(mon.overhead_seconds):
            overheads.append(mon.overhead_seconds)
    payload = {
        "median_slowdown": float(np.median(slowdowns)),
        "max_slowdown": float(np.max(slowdowns)),
        "median_overhead_s": float(np.median(overheads)) if overheads else None,
        "max_overhead_s": float(np.max(overheads)) if overheads else None,
        "never_competitive_frac": 1.0 - len(overheads) / n_queries,
        "n": n_queries,
    }
    print("\n[Exp 2b / Fig 10] online-monitoring baseline vs COSTREAM initial placement")
    for k, v in payload.items():
        print(f"  {k}: {v}")
    save_result("exp2b_fig10", payload)
    return payload


def main():
    exp2a()
    exp2b()


if __name__ == "__main__":
    main()
