"""Continuous-placement controller benchmark: drift + failure recovery.

A fleet of co-located queries starts from a contention-aware oracle
placement on a deliberately weak edge cluster, then a seeded scenario hits
it: event-rate drift (x8) on two queries, a node failure that orphans
everything on the strongest host, and a late capacity join.  Three lanes
ride the SAME deterministic ``FleetRuntime`` (docs/controller.md):

  static      never re-places anything — the pre-controller semantics.  Its
              fleet cost explodes when drift saturates a host and never
              recovers from the failure;
  controller  ``PlacementController`` with the DispatchPolicy knobs:
              EWMA/CUSUM drift detection, incremental re-placement of only
              the implicated operators, migration budget, cooldown;
  oracle      ``replan_every_tick=True``: every query fully re-planned every
              tick with an unbounded budget — the clairvoyant upper bound
              (and the migration-count price of it).

The decision-quality lanes score through a noise-free simulator oracle, so
``static_vs_controller_final`` (static / controller end-of-run fleet cost)
is DETERMINISTIC — a shift means the controller's behavior changed, not
timing noise.  The gates:

  * ``static_vs_controller_final >= --min-ratio`` (the controller must
    actually rescue the fleet);
  * ``controller.max_migration_mb <= DispatchPolicy.migration_budget_mb``
    (budget counter-asserted from the decision log);
  * ``controller.n_migrations <= oracle.n_migrations`` (stability: the
    budgeted/hysteresis loop must move less than the clairvoyant one);
  * replan p95 <= ``--max-replan-p95-ms`` on the ESTIMATOR lane: the same
    scenario re-planned through a real ``CostEstimator`` (tiny random-init
    ensembles — latency of the machinery, not model quality), run twice
    with identical seeds; the first run pays compiles, the warm second run
    is the SLO measurement and must replay the first's decision log
    bit-identically (determinism gate).

    PYTHONPATH=src python benchmarks/controller_bench.py [--quick]
        [--min-ratio X] [--max-replan-p95-ms MS]
        [--baseline FILE --max-regression F]
"""

from __future__ import annotations

import argparse
import json

from repro.control import (
    FleetRuntime,
    PlacementController,
    SimulatorScorer,
    build_scenario,
    run_static,
)
from repro.serve import active_policy

#: The estimator lane's metric set: the re-planner's target plus the two
#: feasibility gates it penalizes on.
METRICS = ("latency_e", "success", "backpressure")


def make_estimator(hidden: int = 32, n_ensemble: int = 2):
    """Tiny random-init ensembles: replan latency of the real scoring
    machinery (skeleton caches, merged cross-query forward), not model
    quality."""
    import jax

    from repro.core import CostModelConfig, GNNConfig, init_cost_model
    from repro.serve import CostEstimator

    models = {}
    for i, metric in enumerate(METRICS):
        cfg = CostModelConfig(
            metric=metric, n_ensemble=n_ensemble, gnn=GNNConfig(hidden=hidden)
        )
        models[metric] = (init_cost_model(jax.random.PRNGKey(i), cfg), cfg)
    return CostEstimator(models)


def run(n_queries: int, n_ticks: int, seed: int = 7) -> dict:
    fleet, cluster, events = build_scenario(n_queries, n_ticks, seed=seed)
    policy = active_policy().validate()

    def runtime() -> FleetRuntime:
        return FleetRuntime(fleet, cluster, events, seed=1, tick_s=policy.controller_tick_s)

    # -- decision-quality lanes: noise-free simulator oracle as the scorer,
    # so every number below is deterministic for the seed pair
    static = run_static(runtime(), n_ticks)
    ctl = PlacementController(runtime(), scorer=SimulatorScorer(), seed=0).run(n_ticks)
    oracle = PlacementController(
        runtime(), scorer=SimulatorScorer(), seed=0, replan_every_tick=True
    ).run(n_ticks)

    # -- latency lane: same scenario through a real CostEstimator.  Run twice
    # with identical seeds: run 1 pays every jit compile, run 2 is warm and is
    # the SLO measurement; its decision log must replay run 1's bit-identically
    est = make_estimator()
    est_cold = PlacementController(runtime(), estimator=est, seed=0).run(n_ticks)
    est_warm = PlacementController(runtime(), estimator=est, seed=0).run(n_ticks)
    if est_warm.decision_log() != est_cold.decision_log():
        raise SystemExit("estimator lane is not deterministic across replays")

    res = {
        "n_queries": n_queries,
        "n_ticks": n_ticks,
        "migration_budget_mb": policy.migration_budget_mb,
        "static": static.to_dict(),
        "controller": ctl.to_dict(),
        "oracle": oracle.to_dict(),
        "estimator_cold": est_cold.to_dict(),
        "estimator_warm": est_warm.to_dict(),
        "static_vs_controller_final": round(
            static.final_cost_ms / max(ctl.final_cost_ms, 1e-9), 3
        ),
        "controller_vs_oracle_final": round(
            ctl.final_cost_ms / max(oracle.final_cost_ms, 1e-9), 3
        ),
        "replan_p95_ms": round(est_warm.replan_p95_ms, 3),
    }
    return res


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--ticks", type=int, default=30)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--quick", action="store_true", help="small run for per-PR CI")
    ap.add_argument(
        "--min-ratio",
        type=float,
        default=None,
        help="fail if static_vs_controller_final is below this",
    )
    ap.add_argument(
        "--max-replan-p95-ms",
        type=float,
        default=None,
        help="fail if the warm estimator lane's replan p95 exceeds this",
    )
    ap.add_argument(
        "--baseline", type=str, default=None, help="JSON with the recorded ratio"
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.10,
        help="allowed fractional drop of the measured ratio below the baseline",
    )
    args = ap.parse_args(argv)
    if args.quick:
        args.queries = min(args.queries, 6)
        args.ticks = min(args.ticks, 20)

    res = run(args.queries, args.ticks, seed=args.seed)
    print(json.dumps(res, indent=2))

    # not assert: these are the CI gate's invariants, they must survive python -O
    budget = res["migration_budget_mb"]
    if res["controller"]["max_migration_mb"] > budget + 1e-9:
        raise SystemExit(
            f"migration budget violated: largest move "
            f"{res['controller']['max_migration_mb']}MB > budget {budget}MB"
        )
    if res["controller"]["n_migrations"] > res["oracle"]["n_migrations"]:
        raise SystemExit(
            f"controller moved more than the replan-every-tick oracle "
            f"({res['controller']['n_migrations']} > {res['oracle']['n_migrations']})"
        )
    if args.min_ratio is not None and res["static_vs_controller_final"] < args.min_ratio:
        raise SystemExit(
            f"static_vs_controller_final {res['static_vs_controller_final']} below "
            f"required {args.min_ratio}"
        )
    if (
        args.max_replan_p95_ms is not None
        and res["replan_p95_ms"] > args.max_replan_p95_ms
    ):
        raise SystemExit(
            f"replan p95 {res['replan_p95_ms']}ms above SLO {args.max_replan_p95_ms}ms"
        )
    if args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
        floor = base["static_vs_controller_final"] * (1.0 - args.max_regression)
        if res["static_vs_controller_final"] < floor:
            raise SystemExit(
                f"static_vs_controller_final {res['static_vs_controller_final']} "
                f"regressed >{args.max_regression:.0%} below recorded baseline "
                f"{base['static_vs_controller_final']} (floor {floor:.3f})"
            )


if __name__ == "__main__":
    main()
