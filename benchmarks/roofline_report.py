"""Aggregate the dry-run artifacts into the SRoofline table (deliverable (g))."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import fmt_table, save_result
from repro.launch import artifacts


def load_cells(mesh: str = "single", tag: str = ""):
    cells = []
    for path in sorted(glob.glob(artifacts.path("dryrun", mesh + tag, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def main(mesh: str = "single"):
    cells = load_cells(mesh)
    rows = []
    for c in cells:
        if c["status"] == "skipped":
            rows.append({"arch": c["arch"], "shape": c["shape"], "bottleneck": "SKIP"})
            continue
        if c["status"] != "ok":
            rows.append({"arch": c["arch"], "shape": c["shape"], "bottleneck": "ERROR"})
            continue
        r = c["roofline"]
        rows.append(
            {
                "arch": c["arch"],
                "shape": c["shape"],
                "t_compute": f"{r['t_compute_s']:.2e}",
                "t_memory": f"{r['t_memory_s']:.2e}",
                "t_coll": f"{r['t_collective_s']:.2e}",
                "bottleneck": r["bottleneck"],
                "useful_flops": f"{r['useful_flops_ratio']:.2f}",
                "roofline_frac": f"{r['roofline_fraction']:.3f}",
                "temp_GB": f"{c['memory']['temp_size_in_bytes'] / 1e9:.1f}",
            }
        )
    print(f"\n[Roofline] mesh={mesh} ({len(rows)} cells)")
    print(
        fmt_table(
            rows,
            [
                "arch",
                "shape",
                "t_compute",
                "t_memory",
                "t_coll",
                "bottleneck",
                "useful_flops",
                "roofline_frac",
                "temp_GB",
            ],
        )
    )
    save_result(f"roofline_{mesh}", rows)
    return rows


if __name__ == "__main__":
    import sys

    main(sys.argv[1] if len(sys.argv) > 1 else "single")
