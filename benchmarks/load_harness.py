"""Open-loop load harness: tail latency of ``PlacementService`` under a
sustained arrival process.

``serve_bench.py`` measures closed-loop drain throughput — the next request
waits for the previous answer, so the service is never pressured beyond its
own pace.  This harness replays a seeded **open-loop** schedule (Poisson and
bursty arrivals over a mixed multi-structure score stream — the paper's
"parallel COSTREAM instances" pattern) and reports what a latency SLO is
written against: p50/p95/p99, SLO-violation rate, and the saturation knee.

Two service configurations run the SAME deterministic stream:

  baseline    the pre-PR serving semantics: no double-buffering, no compile
              warmup, unbounded queue.  It runs FIRST in the process, so its
              latencies include first-request jit compilation — exactly what
              a freshly deployed pre-PR service pays on its opening traffic;
  pipelined   the engineered service: ``start()`` pre-compiles every bucket
              shape the stream can hit (outside the timed window),
              double-buffered drains overlap host featurization with device
              compute, and the bounded queue sheds load instead of growing
              tail latency.

The gated quantity is ``cold_vs_pipelined_p95`` (baseline p95 / pipelined
p95, Poisson schedule): the pipelined service must keep its tail latency
well under the pre-PR cold service at the same offered rate.  The offered
rate is *calibrated* on this machine (a closed-loop serial probe on a
throwaway structure set, so the real structures stay cold for the baseline
run) rather than hardcoded — the harness stresses queueing, not a number
tuned to one container.  A small rate sweep over the pipelined service
locates the saturation knee per schedule.  Methodology: docs/load_harness.md.

    PYTHONPATH=src python benchmarks/load_harness.py [--quick]
        [--min-ratio X]                        # cold_vs_pipelined_p95 floor
        [--baseline FILE --max-regression F]   # ratio gate vs recorded run
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import CostModelConfig, GNNConfig, init_cost_model
from repro.dsps import WorkloadGenerator
from repro.serve import (
    CostEstimator,
    PlacementService,
    bursty_arrivals,
    find_knee,
    poisson_arrivals,
    run_open_loop,
    score_request_stream,
)

METRICS = ("latency_p", "success", "backpressure")


def make_estimator(hidden: int = 32, n_ensemble: int = 2) -> CostEstimator:
    models = {}
    for i, metric in enumerate(METRICS):
        cfg = CostModelConfig(metric=metric, n_ensemble=n_ensemble, gnn=GNNConfig(hidden=hidden))
        models[metric] = (init_cost_model(jax.random.PRNGKey(i), cfg), cfg)
    return CostEstimator(models)


def mixed_structures(n_structures: int, seed: int, name_prefix: str = "load"):
    """n DISTINCT (query, cluster) structures cycling the corpus query kinds."""
    gen = WorkloadGenerator(seed=seed)
    kinds = ("linear", "two_way", "three_way")
    return [
        (
            gen.query(kind=kinds[i % len(kinds)], name=f"{name_prefix}{i}"),
            gen.cluster(3 + i % 6),
        )
        for i in range(n_structures)
    ]


def calibrate_rate(est: CostEstimator, cands: int, seed: int, n_probe: int = 24) -> float:
    """Serial closed-loop score throughput (req/s) on a THROWAWAY structure.

    The probe structure set is disjoint from the measured stream, so its jit
    traces share nothing with the real structures and the baseline service
    still runs cold.  The returned rate anchors the offered load to this
    machine instead of a hardcoded number.
    """
    from repro.placement import sample_assignment_matrix

    (q, c), = mixed_structures(1, seed=seed + 991, name_prefix="calib")
    rng = np.random.default_rng(seed)
    a = sample_assignment_matrix(q, c, cands, rng)
    est.score(q, c, a, METRICS)  # compile outside the probe
    t0 = time.perf_counter()
    for _ in range(n_probe):
        est.score(q, c, a, METRICS)
    return n_probe / (time.perf_counter() - t0)


def _schedule(kind: str, rate: float, n: int, seed: int) -> np.ndarray:
    if kind == "poisson":
        return poisson_arrivals(rate, n, seed=seed)
    assert kind == "bursty", kind
    return bursty_arrivals(rate, n, seed=seed, burst_factor=4.0, burst_fraction=0.25)


def make_baseline_service(est: CostEstimator) -> PlacementService:
    """The pre-PR serving semantics: single-buffered, cold, unbounded."""
    return PlacementService(est, auto_start=True, double_buffer=False)


def make_pipelined_service(est, structures, max_cands: int, depth: int) -> PlacementService:
    return PlacementService(
        est,
        auto_start=True,  # start() runs the warmup before serving
        double_buffer=True,
        warmup=structures,
        warmup_cands=max_cands,
        max_queue_depth=depth,
        overflow="reject",
        # merged traces only for warmed mixes: arbitrary arrival subsets must
        # not each buy a fresh compile mid-run
        max_merged_mixes=0,
    )


def run(
    n_structures: int,
    n_requests: int,
    cands: int,
    repeats: int,
    slo_ms: float,
    seed: int = 0,
    knee_points: int = 4,
) -> dict:
    repeats = max(1, repeats)
    est = make_estimator()
    structures = mixed_structures(n_structures, seed)
    stream = score_request_stream(structures, n_requests, cands, seed=seed, metrics=METRICS)
    rate = calibrate_rate(est, cands, seed)
    slo_s = slo_ms / 1e3

    res: dict = {
        "n_structures": n_structures,
        "n_requests": n_requests,
        "cands_per_request": cands,
        "n_metrics": len(METRICS),
        "repeats": repeats,
        "slo_ms": slo_ms,
        "calibrated_serial_rps": round(rate, 1),
        "offered_rps": round(rate, 1),
    }

    # -- baseline: pre-PR service, COLD (this is the first time the measured
    # structures' traces are touched in this process, by construction) -- it
    # must run before anything else compiles them
    for kind in ("poisson", "bursty"):
        svc = make_baseline_service(est)
        rep = run_open_loop(
            svc, stream(svc), _schedule(kind, rate, n_requests, seed), slo_s=slo_s
        )
        svc.close()
        res[f"baseline_{kind}"] = rep.summary()

    # -- pipelined: warmed at start(), double-buffered, bounded queue.  The
    # gated quantity is best-of-repeats: open-loop tail latency is a ratio of
    # two separately timed windows, and a transient container stall inside
    # either window skews it
    svc = make_pipelined_service(est, structures, cands, depth=max(16, n_requests))
    for kind in ("poisson", "bursty"):
        best = None
        for _ in range(repeats):
            svc.stats.reset()
            rep = run_open_loop(
                svc, stream(svc), _schedule(kind, rate, n_requests, seed), slo_s=slo_s
            )
            if best is None or rep.p95_s < best.p95_s:
                best = rep
        res[f"pipelined_{kind}"] = best.summary()

    # -- double-buffer isolation: identical warm/mix policy, single-buffered
    # -- separates the warmup win (baseline vs this) from the overlap win
    # (this vs pipelined) in the report
    warm_single = PlacementService(
        est,
        auto_start=True,
        double_buffer=False,
        warmup=structures,
        warmup_cands=cands,
        max_merged_mixes=0,
    )
    best = None
    for _ in range(repeats):
        warm_single.stats.reset()
        rep = run_open_loop(
            warm_single, stream(warm_single), _schedule("poisson", rate, n_requests, seed), slo_s=slo_s
        )
        if best is None or rep.p95_s < best.p95_s:
            best = rep
    warm_single.close()
    res["warm_single_poisson"] = best.summary()

    # -- saturation knee: rate sweep on the warmed pipelined service
    for kind in ("poisson", "bursty"):
        factors = np.geomspace(0.25, 4.0, knee_points)

        def at_rate(r: float, _kind=kind) -> "object":
            svc.stats.reset()
            sched = _schedule(_kind, r, max(24, n_requests // 2), seed + 7)
            sub = score_request_stream(
                structures, len(sched), cands, seed=seed + 7, metrics=METRICS
            )(svc)
            return run_open_loop(svc, sub, sched, slo_s=slo_s)

        knee, points = find_knee(at_rate, [rate * f for f in factors], slo_s)
        res[f"knee_{kind}_rps"] = round(knee, 1) if knee is not None else None
        res[f"knee_{kind}_sweep"] = [
            {"rps": round(p.rate, 1), "p95_ms": round(p.p95_s * 1e3, 2),
             "viol": round(p.slo_violation_rate, 3)}
            for p in points
        ]
    svc.close()

    res["cold_vs_pipelined_p95"] = round(
        res["baseline_poisson"]["p95_ms"] / res["pipelined_poisson"]["p95_ms"], 2
    )
    res["cold_vs_pipelined_p95_bursty"] = round(
        res["baseline_bursty"]["p95_ms"] / res["pipelined_bursty"]["p95_ms"], 2
    )
    res["warm_single_vs_pipelined_p95"] = round(
        res["warm_single_poisson"]["p95_ms"] / res["pipelined_poisson"]["p95_ms"], 2
    )
    return res


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--structures", type=int, default=16)
    ap.add_argument("--requests", type=int, default=192)
    ap.add_argument("--cands", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--slo-ms", type=float, default=250.0)
    ap.add_argument("--knee-points", type=int, default=5)
    ap.add_argument("--quick", action="store_true", help="small run for per-PR CI")
    ap.add_argument(
        "--min-ratio",
        type=float,
        default=None,
        help="fail if cold_vs_pipelined_p95 (baseline p95 / pipelined p95) is below this",
    )
    ap.add_argument(
        "--baseline", type=str, default=None, help="JSON with the recorded ratio"
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.10,
        help="allowed fractional drop of the measured ratio below the baseline",
    )
    args = ap.parse_args(argv)
    if args.quick:
        args.requests = min(args.requests, 120)
        args.knee_points = min(args.knee_points, 4)
        args.repeats = 3

    res = run(
        args.structures,
        args.requests,
        args.cands,
        args.repeats,
        args.slo_ms,
        knee_points=args.knee_points,
    )
    print(json.dumps(res, indent=2))

    # not assert: these are the CI gate's invariants, they must survive python -O
    for kind in ("poisson", "bursty"):
        pip = res[f"pipelined_{kind}"]
        if not (pip["p50_ms"] <= pip["p95_ms"] <= pip["p99_ms"]):
            raise SystemExit(f"non-monotone latency quantiles in pipelined_{kind}: {pip}")
    if args.min_ratio is not None and res["cold_vs_pipelined_p95"] < args.min_ratio:
        raise SystemExit(
            f"cold_vs_pipelined_p95 {res['cold_vs_pipelined_p95']} below required "
            f"{args.min_ratio}"
        )
    if args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
        floor = base["cold_vs_pipelined_p95"] * (1.0 - args.max_regression)
        if res["cold_vs_pipelined_p95"] < floor:
            raise SystemExit(
                f"cold_vs_pipelined_p95 {res['cold_vs_pipelined_p95']} regressed >"
                f"{args.max_regression:.0%} below recorded baseline "
                f"{base['cold_vs_pipelined_p95']} (floor {floor:.3f})"
            )


if __name__ == "__main__":
    main()
