"""Kernel microbenchmarks: Pallas (interpret on CPU) vs jnp oracle.

On this CPU container the meaningful wall-clock number is the ORACLE path
(interpret-mode Pallas executes the kernel body in Python per grid program);
the kernel timings are reported for completeness and the correctness deltas
prove the kernels compute the same function. Real-TPU numbers come from the
same harness with interpret=False.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro import nn
from repro.core.graph import SLOT_RANGES
from repro.kernels.banked_mlp.ops import banked_mlp_slotted
from repro.kernels.banked_mlp.ref import banked_mlp_slotted_ref
from repro.kernels.mp_update.ops import mp_update
from repro.kernels.mp_update.ref import mp_update_ref
from repro.kernels.rglru.ops import linear_scan
from repro.kernels.rglru.ref import linear_scan_ref


def _time(fn, *args, iters=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def main():
    rows = []
    # banked MLP
    p = nn.init_mlp_bank(jax.random.PRNGKey(0), 5, [39, 64, 64])
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 12, 39))
    ref = jax.jit(lambda p, x: banked_mlp_slotted_ref(p, x, SLOT_RANGES))
    ker = jax.jit(lambda p, x: banked_mlp_slotted(p, x, SLOT_RANGES))
    err = float(jnp.abs(ref(p, x) - ker(p, x)).max())
    rows.append(("banked_mlp_ref_B256", _time(ref, p, x), f"maxerr={err:.2e}"))
    rows.append(("banked_mlp_pallas_interp_B256", _time(ker, p, x, iters=2), "interpret"))

    # mp_update
    H = 64
    pu = nn.init_mlp_bank(jax.random.PRNGKey(2), 5, [2 * H, H, H])
    h = jax.random.normal(jax.random.PRNGKey(3), (256, 12, H))
    a = (jax.random.uniform(jax.random.PRNGKey(4), (256, 12, 12)) > 0.8).astype(jnp.float32)
    depth = jax.random.randint(jax.random.PRNGKey(5), (256, 12), 0, 6)
    mask = jnp.ones((256, 12))
    d = jnp.asarray(2, jnp.int32)
    refu = jax.jit(lambda: mp_update_ref(pu, h, a, depth, mask, d, SLOT_RANGES))
    keru = jax.jit(lambda: mp_update(pu, h, a, depth, mask, d, SLOT_RANGES))
    err = float(jnp.abs(refu() - keru()).max())
    rows.append(("mp_update_ref_B256", _time(refu), f"maxerr={err:.2e}"))
    rows.append(("mp_update_pallas_interp_B256", _time(keru, iters=2), "interpret"))

    # rglru linear scan
    B, T, D = 4, 1024, 256
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    aa = jax.random.uniform(ks[0], (B, T, D), minval=0.8, maxval=0.999)
    bb = jax.random.normal(ks[1], (B, T, D)) * 0.1
    h0 = jax.random.normal(ks[2], (B, D))
    refs = jax.jit(lambda: linear_scan_ref(aa, bb, h0))
    kers = jax.jit(lambda: linear_scan(aa, bb, h0))
    err = float(jnp.abs(refs() - kers()).max())
    rows.append((f"rglru_ref_B{B}_T{T}_D{D}", _time(refs), f"maxerr={err:.2e}"))
    rows.append((f"rglru_pallas_interp_B{B}_T{T}_D{D}", _time(kers, iters=2), "interpret"))

    print("\n[kernels] name,us_per_call,derived")
    for name, us, extra in rows:
        print(f"{name},{us:.1f},{extra}")
    save_result("kernels_bench", [{"name": n, "us": u, "note": e} for n, u, e in rows])
    return rows


if __name__ == "__main__":
    main()
