"""Shared benchmark utilities: artifact loading, eval corpora, metric eval."""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ALL_METRICS,
    CLASSIFICATION_METRICS,
    REGRESSION_METRICS,
    accuracy,
    balanced_indices,
    batch_graphs,
    build_graph,
    qerror_summary,
)
from repro.core.flat_vector import featurize_flat_traces
from repro.core.model import label_array
from repro.dsps.generator import Trace, WorkloadGenerator
from repro.launch import artifacts
from repro.launch.train import CORPUS_SEED, SPLIT_SEED, main_corpus
from repro.serve import CostEstimator
from repro.training.loop import predict_flat

RESULTS_DIR = artifacts.path("results")


def save_result(name: str, payload: Dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=2, default=str)


def test_split_traces() -> List[Trace]:
    """The held-out 10% of the main corpus (same permutation as training)."""
    traces = main_corpus()
    rng = np.random.default_rng(SPLIT_SEED)
    perm = rng.permutation(len(traces))
    n_tr = int(0.8 * len(traces))
    n_va = int(0.1 * len(traces))
    return [traces[i] for i in perm[n_tr + n_va :]]


def graphs_of(traces: Sequence[Trace], transform=None):
    singles = [build_graph(t.query, t.cluster, t.placement) for t in traces]
    if transform:
        singles = [transform(g) for g in singles]
    return jax.tree_util.tree_map(jnp.asarray, batch_graphs(singles))


def eval_costream(
    traces: Sequence[Trace],
    metrics: Sequence[str] = ALL_METRICS,
    prefix: str = "main",
    transform=None,
    balance: bool = True,
) -> Dict[str, Dict]:
    out: Dict[str, Dict] = {}
    g_all = graphs_of(traces, transform)
    models = {}
    for metric in metrics:
        name = f"{prefix}_{metric}"
        if not artifacts.exists("costream", name):
            out[metric] = {"missing": True}
            continue
        models[metric] = artifacts.load_cost_model(name)
    if not models:
        return out
    # one facade call: all present ensembles fused over the shared batch
    preds = CostEstimator(models).estimate(g_all, metrics=tuple(models))
    for metric, pred in preds.items():
        y = label_array(traces, metric)
        if metric in REGRESSION_METRICS:
            mask = y > 0  # failed runs have zero cost; the paper predicts costs
            out[metric] = qerror_summary(y[mask], pred[mask])
        else:
            idx = (
                balanced_indices(y.astype(int), np.random.default_rng(0))
                if balance
                else np.arange(len(y))
            )
            out[metric] = {"accuracy": accuracy(y[idx], pred[idx]), "n": int(len(idx))}
    return out


def eval_flat(
    traces: Sequence[Trace],
    metrics: Sequence[str] = ALL_METRICS,
    balance: bool = True,
) -> Dict[str, Dict]:
    out: Dict[str, Dict] = {}
    x = featurize_flat_traces(list(traces))
    for metric in metrics:
        name = f"flat_{metric}"
        if not artifacts.exists("flat", name):
            out[metric] = {"missing": True}
            continue
        params, cfg = artifacts.load_flat_model(name)
        y = label_array(traces, metric)
        pred = predict_flat(params, x, cfg.task)
        if metric in REGRESSION_METRICS:
            mask = y > 0
            out[metric] = qerror_summary(y[mask], pred[mask])
        else:
            idx = (
                balanced_indices(y.astype(int), np.random.default_rng(0))
                if balance
                else np.arange(len(y))
            )
            out[metric] = {"accuracy": accuracy(y[idx], pred[idx]), "n": int(len(idx))}
    return out


def serving_estimator(prefix: str = "main") -> CostEstimator:
    """The online-path CostEstimator for ``prefix``'s trained models.

    Prefers the versioned serving bundle (``artifacts/bundles/<prefix>``,
    emitted by launch/train.py); falls back to assembling the loose
    per-metric checkpoints for partially trained runs."""
    if artifacts.bundle_exists(prefix):
        return CostEstimator.from_bundle(artifacts.load_bundle(prefix))
    models = {}
    for metric in ("latency_p", "throughput", "success", "backpressure"):
        name = f"{prefix}_{metric}"
        if artifacts.exists("costream", name):
            models[metric] = artifacts.load_cost_model(name)
    return CostEstimator(models)


class FlatRanker:
    """Candidate ranking with the flat-vector baseline (Fig. 9's comparison)."""

    def __init__(self):
        self.models = {}
        for metric in ("latency_p", "success", "backpressure"):
            name = f"flat_{metric}"
            if artifacts.exists("flat", name):
                self.models[metric] = artifacts.load_flat_model(name)

    def pick(self, query, cluster, assignments: np.ndarray, target="latency_p"):
        """Best candidate from an ``(N, n_ops)`` assignment matrix.

        Consumes the same raw matrix form as ``PlacementOptimizer`` (the
        ``List[Placement]`` wrapper is gone); rows are converted to
        ``Placement`` only at the featurizer boundary and for the winner.
        """
        from repro.core.flat_vector import featurize_flat
        from repro.dsps.placement import Placement

        assignments = np.asarray(assignments, dtype=np.int64)
        x = np.stack(
            [featurize_flat(query, cluster, Placement.of(row)) for row in assignments]
        )
        feasible = np.ones(len(assignments), dtype=bool)
        for m in ("success", "backpressure"):
            if m in self.models:
                params, cfg = self.models[m]
                feasible &= predict_flat(params, x, cfg.task).astype(bool)
        if not feasible.any():
            feasible[:] = True
        params, cfg = self.models[target]
        scores = predict_flat(params, x, cfg.task)
        masked = np.where(feasible, scores, np.inf)
        return Placement.of(assignments[int(np.argmin(masked))])


def fmt_table(rows: List[Dict], cols: List[str]) -> str:
    widths = {c: max(len(c), max((len(str(r.get(c, ""))) for r in rows), default=0)) for c in cols}
    lines = ["  ".join(c.ljust(widths[c]) for c in cols)]
    for r in rows:
        lines.append("  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))
    return "\n".join(lines)
