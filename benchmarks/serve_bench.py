"""Serving microbenchmark: requests/s under three request-stream shapes.

Drives ``repro.serve.PlacementService`` with streams of small requests (the
paper's online pattern: many concurrent "parallel COSTREAM instance"
queries, each scoring a handful of candidates) over the SAME requests,
models, and service code path:

  --mode score (default)
      one hot query structure; ``serial`` (submit, wait, repeat — every
      request pays one full dispatch) vs ``coalesced`` (submit the whole
      stream, then gather — requests pile up and share fused bucket-padded
      stacked forwards);
  --mode mixed
      N DISTINCT query structures round-robin — the heterogeneous stream the
      cross-query broadcast-batch path exists for.  ``grouped``
      (cross_query=False: one forward per structure per drain, the pre-merge
      behavior) vs ``cross`` (cross_query=True: the whole drain merges into
      one signature-banded stacked forward per max_batch rows).  Both modes
      drain a pre-queued stream once (deterministic batch shapes);
  --mode estimate
      cost-estimate requests for batches of placed queries; ``serial`` vs
      ``coalesced`` submission, exercising the estimate coalescing path.

Every mode verifies its answers against direct ``CostEstimator`` calls
before timing, and the verification pass runs the exact drains that are
later timed, so every jit shape is warm and the ratios isolate batching —
not compilation.

    PYTHONPATH=src python benchmarks/serve_bench.py [--mode score|mixed|estimate]
        [--quick]
        [--policy default|tuned]               # dispatch policy (tuned: reported, never gated)
        [--min-speedup X]                      # mode ratio floor
        [--baseline FILE --max-regression F]   # ratio gate vs recorded run
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import CostModelConfig, GNNConfig, init_cost_model
from repro.core.bucketing import bucket_size
from repro.dsps import WorkloadGenerator
from repro.placement import sample_assignment_matrix
from repro.serve import CostEstimator, PlacementService
from repro.serve.policy import DispatchPolicy, active_policy, autotune, use_policy

METRICS = ("latency_p", "success", "backpressure")


def make_estimator(hidden: int = 32, n_ensemble: int = 2) -> CostEstimator:
    models = {}
    for i, metric in enumerate(METRICS):
        cfg = CostModelConfig(metric=metric, n_ensemble=n_ensemble, gnn=GNNConfig(hidden=hidden))
        models[metric] = (init_cost_model(jax.random.PRNGKey(i), cfg), cfg)
    # pick up the bench-selected policy (--policy tuned runs under use_policy)
    return CostEstimator(models, policy=active_policy())


def run(n_requests: int, cands_per_request: int, repeats: int, seed: int = 0) -> dict:
    repeats = max(1, repeats)
    gen = WorkloadGenerator(seed=seed)
    q = gen.query(kind="two_way", name="serve")
    c = gen.cluster(6)
    rng = np.random.default_rng(seed)
    # request payloads may share candidates (realistic: hot queries repeat);
    # cycle the distinct pool to fill n_requests x cands_per_request rows
    pool = sample_assignment_matrix(
        q, c, n_requests * cands_per_request, rng, max_tries_factor=400
    )
    assert len(pool) >= cands_per_request, "not enough distinct candidates"
    idx = np.arange(n_requests * cands_per_request) % len(pool)
    requests = [
        pool[idx[i * cands_per_request : (i + 1) * cands_per_request]]
        for i in range(n_requests)
    ]

    est = make_estimator()
    # warm every bucket shape the coalescer can produce (powers of two from a
    # single request up to the full stream), so timings exclude compilation
    b = bucket_size(cands_per_request)
    while True:
        est.score(q, c, pool[np.arange(b) % len(pool)], METRICS)
        if b >= bucket_size(n_requests * cands_per_request):
            break
        b *= 2

    # correctness first: both submission modes must answer exactly like the
    # shared facade, no matter how requests were batched
    ref = [est.score(q, c, r, METRICS) for r in requests]
    with PlacementService(est) as svc:
        serial = [svc.score(q, c, r, METRICS) for r in requests]
        futs = [svc.submit_score(q, c, r, METRICS) for r in requests]
        coalesced = [f.result() for f in futs]
    for name, got in (("serial", serial), ("coalesced", coalesced)):
        for want, have in zip(ref, got):
            for m in METRICS:
                np.testing.assert_allclose(have[m], want[m], rtol=1e-5, atol=1e-6, err_msg=f"{name}:{m}")

    # best-of-repeats: the gated quantity is a RATIO of two separately timed
    # windows, so a transient container stall inside either window skews it;
    # the per-mode minimum measures steady-state capability instead
    timings = {}
    forwards = {}
    for mode in ("serial", "coalesced"):
        best = np.inf
        with PlacementService(est) as svc:
            for _ in range(repeats):
                svc.stats.reset()
                t0 = time.perf_counter()
                if mode == "serial":
                    for r in requests:
                        svc.score(q, c, r, METRICS)
                else:
                    futs = [svc.submit_score(q, c, r, METRICS) for r in requests]
                    for f in futs:
                        f.result()
                best = min(best, time.perf_counter() - t0)
            forwards[mode] = svc.stats.n_forwards  # last repeat's count
        timings[mode] = best

    rate = {m: n_requests / t for m, t in timings.items()}
    return {
        "n_requests": n_requests,
        "cands_per_request": cands_per_request,
        "n_metrics": len(METRICS),
        "repeats": repeats,
        "serial_s": round(timings["serial"], 4),
        "coalesced_s": round(timings["coalesced"], 4),
        "serial_rps": round(rate["serial"], 1),
        "coalesced_rps": round(rate["coalesced"], 1),
        "serial_forwards": forwards["serial"],
        "coalesced_forwards": forwards["coalesced"],
        "coalesced_vs_serial": round(rate["coalesced"] / rate["serial"], 2),
    }


def _mixed_structures(n_structures: int, seed: int):
    """n DISTINCT (query, cluster) structures cycling the corpus query kinds."""
    gen = WorkloadGenerator(seed=seed)
    kinds = ("linear", "two_way", "three_way")
    return [
        (gen.query(kind=kinds[i % len(kinds)], name=f"mix{i}"), gen.cluster(3 + i % 6))
        for i in range(n_structures)
    ]


def _drain_once(svc, submit):
    """Pre-queue a whole stream, start the worker, gather: ONE deterministic
    drain (stable batch shapes — the methodology for drain-vs-drain ratios)."""
    futs = submit(svc)
    t0 = time.perf_counter()
    svc.start()
    results = [f.result() for f in futs]
    elapsed = time.perf_counter() - t0
    return results, elapsed


def run_mixed(
    n_structures: int, reqs_per_structure: int, cands: int, repeats: int, seed: int = 0
) -> dict:
    """Cross-query coalescing vs the per-structure-group drain on a stream of
    many DISTINCT small queries (requests round-robin the structures, so
    every drain sees all of them interleaved)."""
    repeats = max(1, repeats)
    structures = _mixed_structures(n_structures, seed)
    rng = np.random.default_rng(seed)
    requests = []
    for _ in range(reqs_per_structure):
        for q, c in structures:
            requests.append((q, c, sample_assignment_matrix(q, c, cands, rng)))

    est = make_estimator()
    ref = [est.score(q, c, a, METRICS) for q, c, a in requests]

    def submit(svc):
        return [svc.submit_score(q, c, a, METRICS) for q, c, a in requests]

    def make_svc(mode):
        # row_limit=None: the bench CONTRASTS the two drain strategies, so the
        # cross service must merge rather than adaptively fall back
        return PlacementService(
            est,
            auto_start=False,
            cross_query=(mode == "cross"),
            cross_query_row_limit=None,
        )

    # correctness first (this also warms every drain shape both modes use):
    # cross-query merging must be invisible to callers
    forwards = {}
    for mode in ("grouped", "cross"):
        svc = make_svc(mode)
        got, _ = _drain_once(svc, submit)
        svc.close()
        forwards[mode] = svc.stats.n_forwards
        for want, have in zip(ref, got):
            for m in METRICS:
                np.testing.assert_allclose(
                    have[m], want[m], rtol=1e-4, atol=1e-5, err_msg=f"{mode}:{m}"
                )

    timings = {}
    for mode in ("grouped", "cross"):
        best = np.inf
        for _ in range(repeats):
            svc = make_svc(mode)
            _, elapsed = _drain_once(svc, submit)
            svc.close()
            best = min(best, elapsed)
        timings[mode] = best

    n_requests = len(requests)
    rate = {m: n_requests / t for m, t in timings.items()}
    return {
        "mode": "mixed",
        "n_structures": n_structures,
        "n_requests": n_requests,
        "cands_per_request": cands,
        "n_metrics": len(METRICS),
        "repeats": repeats,
        "grouped_s": round(timings["grouped"], 4),
        "cross_s": round(timings["cross"], 4),
        "grouped_rps": round(rate["grouped"], 1),
        "cross_rps": round(rate["cross"], 1),
        "grouped_forwards": forwards["grouped"],
        "cross_forwards": forwards["cross"],
        "cross_vs_grouped": round(rate["cross"] / rate["grouped"], 2),
    }


def run_estimate(n_requests: int, graphs_per_request: int, repeats: int, seed: int = 0) -> dict:
    """Estimate-request coalescing: serial submit-and-wait vs a pre-queued
    drain of cost-estimate requests for batches of placed queries."""
    from repro.core.graph import batch_graphs, build_graph

    repeats = max(1, repeats)
    traces = WorkloadGenerator(seed=seed).corpus(n_requests * graphs_per_request)
    requests = [
        batch_graphs(
            [
                build_graph(t.query, t.cluster, t.placement)
                for t in traces[i * graphs_per_request : (i + 1) * graphs_per_request]
            ]
        )
        for i in range(n_requests)
    ]
    est = make_estimator()
    ref = [est.estimate(g, METRICS) for g in requests]

    def submit(svc):
        return [svc.submit_estimate(g, METRICS) for g in requests]

    # correctness + warmup for both submission patterns
    with PlacementService(est) as svc:
        serial = [svc.estimate(g, METRICS) for g in requests]
    svc_c = PlacementService(est, auto_start=False)
    coalesced, _ = _drain_once(svc_c, submit)
    svc_c.close()
    coalesced_forwards = svc_c.stats.n_forwards
    for name, got in (("serial", serial), ("coalesced", coalesced)):
        for want, have in zip(ref, got):
            for m in METRICS:
                np.testing.assert_allclose(
                    have[m], want[m], rtol=1e-4, atol=1e-5, err_msg=f"{name}:{m}"
                )

    timings = {}
    forwards = {"coalesced": coalesced_forwards}
    best = np.inf
    with PlacementService(est) as svc:
        for _ in range(repeats):
            svc.stats.reset()
            t0 = time.perf_counter()
            for g in requests:
                svc.estimate(g, METRICS)
            best = min(best, time.perf_counter() - t0)
        forwards["serial"] = svc.stats.n_forwards
    timings["serial"] = best
    best = np.inf
    for _ in range(repeats):
        svc = PlacementService(est, auto_start=False)
        _, elapsed = _drain_once(svc, submit)
        svc.close()
        best = min(best, elapsed)
    timings["coalesced"] = best

    rate = {m: n_requests / t for m, t in timings.items()}
    return {
        "mode": "estimate",
        "n_requests": n_requests,
        "graphs_per_request": graphs_per_request,
        "n_metrics": len(METRICS),
        "repeats": repeats,
        "serial_s": round(timings["serial"], 4),
        "coalesced_s": round(timings["coalesced"], 4),
        "serial_rps": round(rate["serial"], 1),
        "coalesced_rps": round(rate["coalesced"], 1),
        "serial_forwards": forwards["serial"],
        "coalesced_forwards": forwards["coalesced"],
        "coalesced_vs_serial": round(rate["coalesced"] / rate["serial"], 2),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=("score", "mixed", "estimate"), default="score")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument(
        "--cands",
        type=int,
        default=None,
        help="candidates per request (default 8; mixed mode 2 — the "
        "dispatch-bound refinement-loop shape cross-query merging is built "
        "for: each distinct query scores a couple of alternative placements)",
    )
    ap.add_argument(
        "--structures", type=int, default=16, help="distinct query structures (mixed)"
    )
    ap.add_argument(
        "--graphs", type=int, default=4, help="graphs per estimate request"
    )
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--quick", action="store_true", help="small run for per-PR CI")
    ap.add_argument(
        "--policy",
        choices=("default", "tuned"),
        default="default",
        help="dispatch policy for the run: built-in defaults, or the host's "
        "autotuned profile (autotunes quick on first use, then reuses the "
        "cached per-host profile). Tuned runs are REPORTED, never gated: "
        "--min-speedup/--baseline are ignored under --policy tuned so CI "
        "floors stay pinned to the default policy",
    )
    ap.add_argument("--min-speedup", type=float, default=None, help="fail below this")
    ap.add_argument(
        "--baseline",
        type=str,
        default=None,
        help="JSON with this mode's recorded ratio",
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.10,
        help="allowed fractional drop of the measured ratio below the baseline",
    )
    args = ap.parse_args(argv)
    if args.cands is None:
        args.cands = 2 if args.mode == "mixed" else 8
    if args.requests is None:
        args.requests = 48 if args.mode == "mixed" else 96
    if args.quick:
        args.repeats = 3
        args.requests = 32 if args.mode == "mixed" else 48

    if args.policy == "tuned":
        policy = autotune(quick=True).policy  # cached per-host profile after run 1
    else:
        policy = DispatchPolicy()

    with use_policy(policy):
        if args.mode == "mixed":
            reqs_per_structure = max(1, args.requests // args.structures)
            res = run_mixed(args.structures, reqs_per_structure, args.cands, args.repeats)
            ratio_key, fewer = "cross_vs_grouped", ("cross_forwards", "grouped_forwards")
        elif args.mode == "estimate":
            res = run_estimate(args.requests, args.graphs, args.repeats)
            ratio_key, fewer = "coalesced_vs_serial", ("coalesced_forwards", "serial_forwards")
        else:
            res = run(args.requests, args.cands, args.repeats)
            ratio_key, fewer = "coalesced_vs_serial", ("coalesced_forwards", "serial_forwards")
    res["policy"] = args.policy
    res["cross_query_row_limit"] = policy.cross_query_row_limit
    res["score_chunk"] = policy.score_chunk
    print(json.dumps(res, indent=2))
    if args.policy == "tuned":
        # tuned numbers are a report of what host calibration buys; the
        # recorded baselines were measured under the default policy, so
        # gating them here would compare across policies
        return
    # not assert: these are the CI gate's invariants, they must survive python -O
    if res[fewer[0]] >= res[fewer[1]]:
        raise SystemExit(
            "batching must issue fewer forwards than the baseline drain, got "
            f"{res[fewer[0]]} vs {res[fewer[1]]}"
        )
    if args.min_speedup is not None and res[ratio_key] < args.min_speedup:
        raise SystemExit(
            f"{ratio_key} speedup {res[ratio_key]}x below required {args.min_speedup}x"
        )
    if args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
        floor = base[ratio_key] * (1.0 - args.max_regression)
        if res[ratio_key] < floor:
            raise SystemExit(
                f"{ratio_key} ratio {res[ratio_key]} regressed >"
                f"{args.max_regression:.0%} below recorded baseline "
                f"{base[ratio_key]} (floor {floor:.3f})"
            )


if __name__ == "__main__":
    main()
