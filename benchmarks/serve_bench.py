"""Serving microbenchmark: requests/s, one-at-a-time vs micro-batched.

Drives ``repro.serve.PlacementService`` with a stream of small placement-
scoring requests (the paper's online pattern: many concurrent "parallel
COSTREAM instance" queries, each scoring a handful of candidates) in two
submission modes over the SAME requests, models, and service code path:

  serial     submit one request, wait for its result, submit the next —
             queue depth never builds, so every request pays one full
             dispatch (the fixed per-forward overhead dominates these small
             graphs);
  coalesced  submit the whole stream, then gather — requests pile up while
             the worker is busy and get coalesced into a few fused
             bucket-padded stacked forwards.

Both modes are verified against direct ``CostEstimator.score`` answers
before timing, and all bucket shapes the coalescer can produce are warmed
up front, so the ratio isolates micro-batching — not compilation.

    PYTHONPATH=src python benchmarks/serve_bench.py [--quick]
        [--min-speedup X]                      # coalesced/serial rps floor
        [--baseline FILE --max-regression F]   # ratio gate vs recorded run
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import CostModelConfig, GNNConfig, init_cost_model
from repro.core.bucketing import bucket_size
from repro.dsps import WorkloadGenerator
from repro.placement import sample_assignment_matrix
from repro.serve import CostEstimator, PlacementService

METRICS = ("latency_p", "success", "backpressure")


def make_estimator(hidden: int = 32, n_ensemble: int = 2) -> CostEstimator:
    models = {}
    for i, metric in enumerate(METRICS):
        cfg = CostModelConfig(metric=metric, n_ensemble=n_ensemble, gnn=GNNConfig(hidden=hidden))
        models[metric] = (init_cost_model(jax.random.PRNGKey(i), cfg), cfg)
    return CostEstimator(models)


def run(n_requests: int, cands_per_request: int, repeats: int, seed: int = 0) -> dict:
    repeats = max(1, repeats)
    gen = WorkloadGenerator(seed=seed)
    q = gen.query(kind="two_way", name="serve")
    c = gen.cluster(6)
    rng = np.random.default_rng(seed)
    # request payloads may share candidates (realistic: hot queries repeat);
    # cycle the distinct pool to fill n_requests x cands_per_request rows
    pool = sample_assignment_matrix(
        q, c, n_requests * cands_per_request, rng, max_tries_factor=400
    )
    assert len(pool) >= cands_per_request, "not enough distinct candidates"
    idx = np.arange(n_requests * cands_per_request) % len(pool)
    requests = [
        pool[idx[i * cands_per_request : (i + 1) * cands_per_request]]
        for i in range(n_requests)
    ]

    est = make_estimator()
    # warm every bucket shape the coalescer can produce (powers of two from a
    # single request up to the full stream), so timings exclude compilation
    b = bucket_size(cands_per_request)
    while True:
        est.score(q, c, pool[np.arange(b) % len(pool)], METRICS)
        if b >= bucket_size(n_requests * cands_per_request):
            break
        b *= 2

    # correctness first: both submission modes must answer exactly like the
    # shared facade, no matter how requests were batched
    ref = [est.score(q, c, r, METRICS) for r in requests]
    with PlacementService(est) as svc:
        serial = [svc.score(q, c, r, METRICS) for r in requests]
        futs = [svc.submit_score(q, c, r, METRICS) for r in requests]
        coalesced = [f.result() for f in futs]
    for name, got in (("serial", serial), ("coalesced", coalesced)):
        for want, have in zip(ref, got):
            for m in METRICS:
                np.testing.assert_allclose(have[m], want[m], rtol=1e-5, atol=1e-6, err_msg=f"{name}:{m}")

    # best-of-repeats: the gated quantity is a RATIO of two separately timed
    # windows, so a transient container stall inside either window skews it;
    # the per-mode minimum measures steady-state capability instead
    timings = {}
    forwards = {}
    for mode in ("serial", "coalesced"):
        best = np.inf
        with PlacementService(est) as svc:
            for _ in range(repeats):
                svc.stats.reset()
                t0 = time.perf_counter()
                if mode == "serial":
                    for r in requests:
                        svc.score(q, c, r, METRICS)
                else:
                    futs = [svc.submit_score(q, c, r, METRICS) for r in requests]
                    for f in futs:
                        f.result()
                best = min(best, time.perf_counter() - t0)
            forwards[mode] = svc.stats.n_forwards  # last repeat's count
        timings[mode] = best

    rate = {m: n_requests / t for m, t in timings.items()}
    return {
        "n_requests": n_requests,
        "cands_per_request": cands_per_request,
        "n_metrics": len(METRICS),
        "repeats": repeats,
        "serial_s": round(timings["serial"], 4),
        "coalesced_s": round(timings["coalesced"], 4),
        "serial_rps": round(rate["serial"], 1),
        "coalesced_rps": round(rate["coalesced"], 1),
        "serial_forwards": forwards["serial"],
        "coalesced_forwards": forwards["coalesced"],
        "coalesced_vs_serial": round(rate["coalesced"] / rate["serial"], 2),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--cands", type=int, default=8, help="candidates per request")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--quick", action="store_true", help="small run for per-PR CI")
    ap.add_argument("--min-speedup", type=float, default=None, help="fail below this")
    ap.add_argument(
        "--baseline",
        type=str,
        default=None,
        help="JSON with a recorded coalesced_vs_serial ratio",
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.10,
        help="allowed fractional drop of the measured ratio below the baseline",
    )
    args = ap.parse_args(argv)
    if args.quick:
        args.requests, args.repeats = 48, 3

    res = run(args.requests, args.cands, args.repeats)
    print(json.dumps(res, indent=2))
    # not assert: these are the CI gate's invariants, they must survive python -O
    if res["coalesced_forwards"] >= res["serial_forwards"]:
        raise SystemExit(
            "coalescing must issue fewer forwards than serial submission, got "
            f"{res['coalesced_forwards']} vs {res['serial_forwards']}"
        )
    if args.min_speedup is not None and res["coalesced_vs_serial"] < args.min_speedup:
        raise SystemExit(
            f"coalescing speedup {res['coalesced_vs_serial']}x below required "
            f"{args.min_speedup}x"
        )
    if args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
        floor = base["coalesced_vs_serial"] * (1.0 - args.max_regression)
        if res["coalesced_vs_serial"] < floor:
            raise SystemExit(
                f"coalesced_vs_serial ratio {res['coalesced_vs_serial']} regressed >"
                f"{args.max_regression:.0%} below recorded baseline "
                f"{base['coalesced_vs_serial']} (floor {floor:.3f})"
            )


if __name__ == "__main__":
    main()
