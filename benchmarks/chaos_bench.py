"""Chaos benchmark: open-loop serving under seeded fault injection.

``load_harness.py`` answers "how fast is the healthy service"; this harness
answers "what happens to everyone else when part of it is NOT healthy".  Each
chaos profile from ``repro.serve.chaos`` (forward raises, forward hangs, NaN
outputs, slow host) is driven through the SAME deterministic open-loop score
stream in three request windows:

    healthy prefix   requests [0, n/3)    injector disabled
    faulted window   requests [n/3, 2n/3) injector enabled
    recovery suffix  requests [2n/3, n)   injector disabled again

and the run is judged on *blast radius*, not raw speed:

* **zero lost futures** — every request in every profile resolves (answered,
  never dropped); under the score path's retry -> heuristic-fallback
  degradation there must be zero client-visible failures as well;
* **non-faulted p95** — p95 latency over the healthy + recovery windows,
  reported as a ratio against the same windows of a no-fault control run of
  the identical stream.  The gated scalar ``nonfaulted_p95_ratio_worst`` is
  the worst such ratio across profiles: a fault window must not poison the
  tail of requests outside it;
* fault-path accounting — injections fired, retries, degraded answers,
  non-finite detections, breaker opens (all from ``ServiceStats`` /
  ``CircuitBreaker``), plus a median/MAD straggler count of faulted-window
  latencies (``repro.launch.faults.straggler_outliers``) for the slow-host
  profile.

A corrupt-bundle phase runs outside the load loop: a real saved bundle is
byte-flipped on disk (``chaos.corrupt_bundle``) and must be rejected by
``CostModelBundle.load(verify=True)`` before it ever reaches a swap.

All fault probabilities/severities live in the profile catalog
(``chaos.profiles``); all serving thresholds the faults exercise (retry,
breaker) live on ``DispatchPolicy``.  Methodology: docs/robustness.md.

    PYTHONPATH=src python benchmarks/chaos_bench.py [--quick]
        [--p95-budget X]                       # absolute worst-ratio ceiling
        [--baseline FILE --max-regression F]   # ratio gate vs recorded run
"""

from __future__ import annotations

import argparse
import json
import tempfile

import jax
import numpy as np

from repro.core import CostModelConfig, GNNConfig, init_cost_model
from repro.dsps import WorkloadGenerator
from repro.launch.faults import straggler_outliers
from repro.serve import (
    BundleIntegrityError,
    CostEstimator,
    CostModelBundle,
    PlacementService,
    latency_quantiles,
    poisson_arrivals,
    run_open_loop,
    score_request_stream,
)
from repro.serve.chaos import corrupt_bundle, profiles

METRICS = ("latency_p", "success", "backpressure")


def _models(hidden: int = 16, n_ensemble: int = 2):
    models = {}
    for i, metric in enumerate(METRICS):
        cfg = CostModelConfig(metric=metric, n_ensemble=n_ensemble, gnn=GNNConfig(hidden=hidden))
        models[metric] = (init_cost_model(jax.random.PRNGKey(i), cfg), cfg)
    return models


def mixed_structures(n_structures: int, seed: int):
    """Distinct structures over exactly TWO shape classes: jit traces are
    shape-keyed, so limiting shape diversity keeps the warmup ladder (and a
    fault-stalled drain's compile exposure) bounded while the request mix
    stays heterogeneous."""
    gen = WorkloadGenerator(seed=seed)
    kinds = ("linear", "two_way")
    return [
        (gen.query(kind=kinds[i % 2], name=f"chaos{i}"), gen.cluster(3 + i % 2))
        for i in range(n_structures)
    ]


def warm_shapes(est, structures, cands: int, max_rows: int, seed: int) -> int:
    """Compile every pow2 row bucket a coalesced drain can reach.

    A fault-stalled drain coalesces its backlog into bigger per-structure
    candidate matrices than healthy traffic ever builds; without this, the
    first stall buys multi-second XLA compiles *inside the faulted window*
    and the measured 'blast radius' is dominated by compile time, which a
    long-running service pays once, not per fault."""
    from repro.core.bucketing import bucket_size
    from repro.placement import sample_assignment_matrix

    rng = np.random.default_rng(seed)
    sizes = []
    r = max(1, cands)
    while True:
        b = bucket_size(r)
        sizes.append(b)
        if b >= max_rows:
            break
        r = b + 1
    for q, c in structures:
        for r in sizes:
            est.score(q, c, sample_assignment_matrix(q, c, r, rng), METRICS)
    return len(sizes)


def calibrate_rate(est, structures, cands: int, seed: int, n_probe: int = 16) -> float:
    """Serial closed-loop score rate on the measured structures (they may be
    warm — chaos runs are judged on blast radius, not cold-start)."""
    import time

    from repro.placement import sample_assignment_matrix

    rng = np.random.default_rng(seed)
    q, c = structures[0]
    a = sample_assignment_matrix(q, c, cands, rng)
    est.score(q, c, a, METRICS)  # compile outside the probe
    t0 = time.perf_counter()
    for _ in range(n_probe):
        est.score(q, c, a, METRICS)
    return n_probe / (time.perf_counter() - t0)


def run_profile(
    name,
    injector,
    est,
    structures,
    rate,
    n_requests,
    cands,
    seed,
    settle_s: float = 2.0,
    straggler_z: float = 3.0,
):
    """One profile through the three-window stream; returns (summary, p95s)."""
    svc = PlacementService(
        est,
        auto_start=True,
        double_buffer=True,
        cross_query=False,  # per-structure drains: shapes covered by warm_shapes
        warmup=structures,
        warmup_cands=cands,
        max_queue_depth=max(64, n_requests),  # deep: judging latency, not shedding
        overflow="reject",
        max_merged_mixes=0,
        seed=seed,
    )
    n1, n2 = n_requests // 3, 2 * n_requests // 3
    if injector is not None:
        injector.enabled = False
        est.add_hook(injector)
    try:
        base = score_request_stream(structures, n_requests, cands, seed=seed, metrics=METRICS)(svc)

        def windowed(i, fire):
            def go():
                if injector is not None:
                    # the window is request-indexed, so the fault schedule is
                    # a pure function of (profile seed, stream seed)
                    injector.enabled = n1 <= i < n2
                return fire()

            return go

        submits = [windowed(i, f) for i, f in enumerate(base)]
        # three independent arrival segments separated by settle gaps: the
        # faulted window's queue backlog must drain before the recovery
        # window is measured, or recovery latencies measure leftover
        # queueing, not recovery
        a1 = poisson_arrivals(rate, n1, seed=seed)
        a2 = poisson_arrivals(rate, n2 - n1, seed=seed + 1) + a1[-1] + settle_s
        a3 = poisson_arrivals(rate, n_requests - n2, seed=seed + 2) + a2[-1] + settle_s
        arrivals = np.concatenate([a1, a2, a3])
        rep = run_open_loop(svc, submits, arrivals, slo_s=None, timeout_s=600.0)
    finally:
        if injector is not None:
            est.remove_hook(injector)
        stats = svc.stats
        n_opens = svc.breaker.n_opens
        svc.close()

    lost = rep.n_requests - (rep.n_answered + rep.n_rejected + rep.n_failed)
    if lost != 0 or rep.n_rejected != 0:
        raise SystemExit(f"[{name}] lost/rejected futures: lost={lost} rejected={rep.n_rejected}")
    if rep.n_failed != 0:
        raise SystemExit(
            f"[{name}] {rep.n_failed} client-visible failures; the score path "
            "must degrade, not fail"
        )
    # with zero rejected/failed, latencies align 1:1 with request index
    lat = rep.latencies_s
    nonfaulted = np.concatenate([lat[:n1], lat[n2:]])
    _, nf_p95, _ = latency_quantiles(nonfaulted)
    _, f_p95, _ = latency_quantiles(lat[n1:n2])
    stragglers = straggler_outliers(
        {i: float(v) for i, v in enumerate(lat[n1:n2])}, straggler_z
    )
    summary = {
        "n_requests": rep.n_requests,
        "n_answered": rep.n_answered,
        "n_injected": injector.n_injected if injector is not None else 0,
        "nonfaulted_p95_ms": round(nf_p95 * 1e3, 3),
        "faulted_p95_ms": round(f_p95 * 1e3, 3),
        "n_retries": stats.n_retries,
        "n_degraded": stats.n_degraded,
        "n_nonfinite": stats.n_nonfinite,
        "n_failed_stat": stats.n_failed,
        "breaker_opens": n_opens,
        "n_faulted_window_stragglers": len(stragglers),
    }
    return summary, nf_p95


def corrupt_bundle_phase(seed: int) -> dict:
    """Save a real bundle, byte-flip it, and require verify-time rejection."""
    bundle = CostModelBundle(_models(hidden=8, n_ensemble=1), meta={"note": "chaos"})
    with tempfile.TemporaryDirectory() as d:
        bundle.save(d)
        CostModelBundle.load(d, verify=True)  # pristine copy passes
        path = corrupt_bundle(d, seed=seed)
        try:
            CostModelBundle.load(d, verify=True)
        except BundleIntegrityError as e:
            return {"rejected": True, "corrupted_file": path.rsplit("/", 2)[-1], "error": str(e)[:120]}
    raise SystemExit("corrupt bundle passed load(verify=True)")


def run(
    n_structures: int,
    n_requests: int,
    cands: int,
    seed: int,
    rate_factor: float,
    settle_s: float,
) -> dict:
    est = CostEstimator(_models())
    structures = mixed_structures(n_structures, seed)
    # worst-case coalescing: one structure's whole request share in one drain
    max_rows = -(-n_requests // max(1, n_structures)) * cands
    n_buckets = warm_shapes(est, structures, cands, max_rows, seed)
    serial = calibrate_rate(est, structures, cands, seed)
    # offer a small fraction of serial capacity: faults add service time, and
    # the harness must keep the healthy windows below saturation so
    # non-faulted p95 measures blast radius, not queueing collapse
    rate = serial * rate_factor

    res: dict = {
        "n_structures": n_structures,
        "n_requests": n_requests,
        "cands_per_request": cands,
        "seed": seed,
        "calibrated_serial_rps": round(serial, 1),
        "offered_rps": round(rate, 1),
        "warmed_row_buckets": n_buckets,
    }

    control, control_p95 = run_profile(
        "none", None, est, structures, rate, n_requests, cands, seed, settle_s
    )
    res["profile_none"] = control

    worst = 0.0
    for name, factory in profiles(seed).items():
        summary, nf_p95 = run_profile(
            name, factory(), est, structures, rate, n_requests, cands, seed, settle_s
        )
        if summary["n_injected"] == 0:
            raise SystemExit(f"[{name}] injector never fired; the profile tested nothing")
        ratio = nf_p95 / control_p95 if control_p95 > 0 else float("inf")
        summary["nonfaulted_p95_ratio"] = round(ratio, 3)
        worst = max(worst, ratio)
        res[f"profile_{name}"] = summary

    res["corrupt_bundle"] = corrupt_bundle_phase(seed)
    res["nonfaulted_p95_ratio_worst"] = round(worst, 3)
    return res


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--structures", type=int, default=8)
    ap.add_argument("--requests", type=int, default=120)
    ap.add_argument("--cands", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--rate-factor",
        type=float,
        default=0.25,
        help="offered rate as a fraction of calibrated serial capacity",
    )
    ap.add_argument(
        "--settle-s",
        type=float,
        default=2.0,
        help="quiet gap between request windows so backlog drains before "
        "the next window is measured",
    )
    ap.add_argument("--quick", action="store_true", help="small run for per-PR CI")
    ap.add_argument(
        "--p95-budget",
        type=float,
        default=6.0,
        help="absolute ceiling on nonfaulted_p95_ratio_worst",
    )
    ap.add_argument(
        "--baseline", type=str, default=None, help="JSON with the recorded ratio"
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.10,
        help="allowed fractional growth of the worst ratio above the baseline",
    )
    args = ap.parse_args(argv)
    if args.quick:
        args.structures = min(args.structures, 6)
        args.requests = min(args.requests, 90)

    res = run(
        args.structures, args.requests, args.cands, args.seed, args.rate_factor, args.settle_s
    )
    print(json.dumps(res, indent=2))

    # not assert: these are the CI gate's invariants, they must survive python -O
    if res["profile_nan"]["n_nonfinite"] == 0:
        raise SystemExit("nan profile produced no NonFiniteEstimate detections")
    if res["nonfaulted_p95_ratio_worst"] > args.p95_budget:
        raise SystemExit(
            f"nonfaulted_p95_ratio_worst {res['nonfaulted_p95_ratio_worst']} over "
            f"budget {args.p95_budget}"
        )
    if args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
        ceiling = base["nonfaulted_p95_ratio_worst"] * (1.0 + args.max_regression)
        # latency-ratio gates are one-sided: lower is strictly better
        if res["nonfaulted_p95_ratio_worst"] > ceiling:
            raise SystemExit(
                f"nonfaulted_p95_ratio_worst {res['nonfaulted_p95_ratio_worst']} "
                f"regressed >{args.max_regression:.0%} above recorded baseline "
                f"{base['nonfaulted_p95_ratio_worst']} (ceiling {ceiling:.3f})"
            )


if __name__ == "__main__":
    main()
