"""[Exp 1] General prediction accuracy (paper Table III, Fig. 7, Fig. 8).

Overall q-errors/accuracy on the held-out test split, COSTREAM vs. the flat
vector baseline; then grouped by hardware feature buckets (Fig. 7) and by
query type (Fig. 8).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (
    eval_costream,
    eval_flat,
    fmt_table,
    save_result,
    test_split_traces,
)
from repro.core import ALL_METRICS, REGRESSION_METRICS
from repro.dsps.query import OpType


def table3():
    traces = test_split_traces()
    cs = eval_costream(traces)
    fv = eval_flat(traces)
    rows = []
    for m in ALL_METRICS:
        if m in REGRESSION_METRICS:
            rows.append(
                {
                    "metric": m,
                    "costream_q50": round(cs[m].get("q50", float("nan")), 2),
                    "costream_q95": round(cs[m].get("q95", float("nan")), 2),
                    "flat_q50": round(fv[m].get("q50", float("nan")), 2),
                    "flat_q95": round(fv[m].get("q95", float("nan")), 2),
                }
            )
        else:
            rows.append(
                {
                    "metric": m,
                    "costream_q50": f"{100 * cs[m].get('accuracy', float('nan')):.1f}%",
                    "costream_q95": "",
                    "flat_q50": f"{100 * fv[m].get('accuracy', float('nan')):.1f}%",
                    "flat_q95": "",
                }
            )
    print("\n[Exp 1 / Table III] overall test set (n=%d)" % len(traces))
    print(fmt_table(rows, ["metric", "costream_q50", "costream_q95", "flat_q50", "flat_q95"]))
    save_result("exp1_table3", {"rows": rows, "n": len(traces)})
    return rows


def fig7_hardware_buckets(n_buckets: int = 4):
    traces = test_split_traces()
    feats = {
        "cpu": lambda t: np.mean([n.cpu for n in t.cluster.nodes]),
        "ram": lambda t: np.mean([n.ram_mb for n in t.cluster.nodes]),
        "bandwidth": lambda t: np.mean([n.bandwidth_mbps for n in t.cluster.nodes]),
        "latency": lambda t: np.mean([n.latency_ms for n in t.cluster.nodes]),
    }
    out = {}
    for fname, fn in feats.items():
        vals = np.array([fn(t) for t in traces])
        edges = np.quantile(vals, np.linspace(0, 1, n_buckets + 1))
        rows = []
        for b in range(n_buckets):
            sel = (vals >= edges[b]) & (vals <= edges[b + 1])
            sub = [t for t, s in zip(traces, sel) if s]
            if len(sub) < 20:
                continue
            r = eval_costream(sub, metrics=("latency_e", "backpressure"))
            rows.append(
                {
                    "bucket": f"[{edges[b]:.0f},{edges[b + 1]:.0f}]",
                    "n": len(sub),
                    "latency_e_q50": round(r["latency_e"].get("q50", float("nan")), 2),
                    "bp_acc": f"{100 * r['backpressure'].get('accuracy', float('nan')):.1f}%",
                }
            )
        out[fname] = rows
        print(f"\n[Exp 1 / Fig 7] grouped by mean {fname}")
        print(fmt_table(rows, ["bucket", "n", "latency_e_q50", "bp_acc"]))
    save_result("exp1_fig7", out)
    return out


def fig8_query_types():
    traces = test_split_traces()
    kinds = {
        "linear": lambda q: q.count(OpType.JOIN) == 0,
        "2-way-join": lambda q: q.count(OpType.JOIN) == 1,
        "3-way-join": lambda q: q.count(OpType.JOIN) == 2,
    }
    rows = []
    for name, sel in kinds.items():
        sub = [t for t in traces if sel(t.query)]
        r = eval_costream(sub)
        rows.append(
            {
                "type": name,
                "n": len(sub),
                "T_q50": round(r["throughput"].get("q50", float("nan")), 2),
                "Lp_q50": round(r["latency_p"].get("q50", float("nan")), 2),
                "Le_q50": round(r["latency_e"].get("q50", float("nan")), 2),
                "S_acc": f"{100 * r['success'].get('accuracy', float('nan')):.1f}%",
                "Ro_acc": f"{100 * r['backpressure'].get('accuracy', float('nan')):.1f}%",
            }
        )
    print("\n[Exp 1 / Fig 8] grouped by query type")
    print(fmt_table(rows, ["type", "n", "T_q50", "Lp_q50", "Le_q50", "S_acc", "Ro_acc"]))
    save_result("exp1_fig8", rows)
    return rows


def main():
    table3()
    fig7_hardware_buckets()
    fig8_query_types()


if __name__ == "__main__":
    main()
