"""Placement-search microbenchmark: candidates scored per second.

Compares the two scoring paths of ``PlacementOptimizer`` on the same
candidate set and the same (untrained) per-metric ensembles:

  seed path   ``score_candidates``  — per-candidate ``build_graph`` loop,
              graph batch rebuilt + re-transferred once PER METRIC;
  fast path   ``score_assignments`` — one ``build_graph_batch``
              materialization shared by ALL metric ensembles.

Also counts graph materializations per path (the fast path must build each
candidate graph exactly once across all metrics).  Untrained ensembles are
fine here: scoring throughput does not depend on the weights' values.

    PYTHONPATH=src python benchmarks/placement_bench.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

import repro.core.graph as graph_mod
import repro.placement.optimizer as optimizer_mod
from repro.core import CostModelConfig, GNNConfig, init_cost_model
from repro.dsps import WorkloadGenerator
from repro.dsps.placement import Placement
from repro.placement import PlacementOptimizer, sample_assignment_matrix

METRICS = ("latency_p", "success", "backpressure")


class BuildCounter:
    """Counts candidate-graph materializations in both build entry points."""

    def __init__(self):
        self.single = 0  # build_graph calls (one candidate each)
        self.batch = 0  # candidates materialized via build_graph_batch

    def install(self):
        self._orig_single = graph_mod.build_graph
        self._orig_batch = graph_mod.build_graph_batch
        self._orig_place = graph_mod.build_a_place_batch

        def counted_single(*a, **kw):
            self.single += 1
            return self._orig_single(*a, **kw)

        def counted_batch(query, cluster, assignments, *a, **kw):
            # no count here: build_graph_batch delegates to build_a_place_batch
            # (patched below), which counts the candidates exactly once
            return self._orig_batch(query, cluster, assignments, *a, **kw)

        def counted_place(query, cluster, assignments, *a, **kw):
            self.batch += len(np.asarray(assignments))
            return self._orig_place(query, cluster, assignments, *a, **kw)

        graph_mod.build_graph = counted_single
        graph_mod.build_graph_batch = counted_batch
        graph_mod.build_a_place_batch = counted_place
        # the optimizer imported the names directly; patch its module globals too
        optimizer_mod.build_graph = counted_single
        optimizer_mod.build_graph_batch = counted_batch
        optimizer_mod.build_a_place_batch = counted_place
        return self

    def uninstall(self):
        graph_mod.build_graph = self._orig_single
        graph_mod.build_graph_batch = self._orig_batch
        graph_mod.build_a_place_batch = self._orig_place
        optimizer_mod.build_graph = self._orig_single
        optimizer_mod.build_graph_batch = self._orig_batch
        optimizer_mod.build_a_place_batch = self._orig_place

    @property
    def total(self) -> int:
        return self.single + self.batch


def make_optimizer(hidden: int = 32, n_ensemble: int = 3) -> PlacementOptimizer:
    models = {}
    for i, metric in enumerate(METRICS):
        cfg = CostModelConfig(metric=metric, n_ensemble=n_ensemble, gnn=GNNConfig(hidden=hidden))
        models[metric] = (init_cost_model(jax.random.PRNGKey(i), cfg), cfg)
    return PlacementOptimizer(models)


def run(n_candidates: int, repeats: int, seed: int = 0) -> dict:
    repeats = max(1, repeats)
    gen = WorkloadGenerator(seed=seed)
    q = gen.query(kind="two_way", name="bench")
    c = gen.cluster(6)
    rng = np.random.default_rng(seed)
    a = sample_assignment_matrix(q, c, n_candidates, rng, max_tries_factor=200)
    if len(a) != n_candidates:
        raise SystemExit(f"only {len(a)}/{n_candidates} distinct candidates available")
    candidates = [Placement.of(row) for row in a]
    opt = make_optimizer()

    def seed_path():
        return {m: opt.score_candidates(q, c, candidates, m) for m in METRICS}

    def fast_path():
        return opt.score_assignments(q, c, a, METRICS)

    # warm up the jit caches at the benchmark's bucket shape, then verify the
    # two paths agree before trusting the timings
    ref, got = seed_path(), fast_path()
    for m in METRICS:
        np.testing.assert_allclose(got[m], ref[m], rtol=1e-5, atol=1e-6, err_msg=m)

    counter = BuildCounter().install()
    try:
        t0 = time.perf_counter()
        for _ in range(repeats):
            seed_path()
        t_seed = (time.perf_counter() - t0) / repeats
        seed_builds = counter.total / repeats

        counter.single = counter.batch = 0
        t0 = time.perf_counter()
        for _ in range(repeats):
            fast_path()
        t_fast = (time.perf_counter() - t0) / repeats
        fast_builds = counter.total / repeats
    finally:
        counter.uninstall()

    return {
        "n_candidates": n_candidates,
        "n_metrics": len(METRICS),
        "repeats": repeats,
        "seed_path_s": round(t_seed, 4),
        "fast_path_s": round(t_fast, 4),
        "seed_cands_per_s": round(n_candidates / t_seed, 1),
        "fast_cands_per_s": round(n_candidates / t_fast, 1),
        "speedup": round(t_seed / t_fast, 2),
        "seed_builds_per_candidate": round(seed_builds / n_candidates, 2),
        "fast_builds_per_candidate": round(fast_builds / n_candidates, 2),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--candidates", type=int, default=1024)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--quick", action="store_true", help="small run for per-PR CI")
    ap.add_argument("--min-speedup", type=float, default=None, help="fail below this")
    args = ap.parse_args(argv)
    if args.quick:
        args.candidates, args.repeats = 256, 1

    res = run(args.candidates, args.repeats)
    print(json.dumps(res, indent=2))
    # not assert: this is the CI gate's invariant, it must survive python -O
    if res["fast_builds_per_candidate"] != 1.0:
        raise SystemExit(
            "fast path must build each candidate graph exactly once, got "
            f"{res['fast_builds_per_candidate']}"
        )
    if args.min_speedup is not None and res["speedup"] < args.min_speedup:
        raise SystemExit(
            f"scoring speedup {res['speedup']}x below required {args.min_speedup}x"
        )


if __name__ == "__main__":
    main()
