"""Placement-search microbenchmark: candidates scored per second.

Compares four scoring paths on the same candidate set and the same
(untrained) per-metric ensembles:

  seed path     ``score_candidates``   — per-candidate ``build_graph`` loop,
                graph batch rebuilt + re-transferred once PER METRIC;
  unfused path  the PR-1 fast path — one skeleton, but one
                ``placed_predict`` forward per metric (E launches each);
  fused path    ``CostEstimator.score`` (via ``score_assignments``) —
                per-metric ensembles stacked into ONE vmapped forward
                (``placed_predict_fused``), jnp banks;
  fused+pallas  the fused path with ``use_pallas=True``: stage-0/1/2 through
                the banked-MLP kernel, stage-3 through mp-update.  NOTE the
                kernel ops lower per backend (``kernels.active_lowering``):
                off-TPU the default lowering is the jnp oracle, so on this
                container ``pallas_vs_jnp`` measures the routing RESTRUCTURE
                (trimmed spans, banded mp-update), not Pallas codegen — the
                kernel-body win is a TPU measurement.

Also counts graph materializations per path (the fast paths must build each
candidate graph exactly once across all metrics).  Untrained ensembles are
fine here: scoring throughput does not depend on the weights' values.

    PYTHONPATH=src python benchmarks/placement_bench.py [--quick]
        [--min-speedup X]                 # fused vs seed floor
        [--baseline FILE --max-regression F]   # ratio gate vs recorded run
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.graph as graph_mod
import repro.placement.optimizer as optimizer_mod
import repro.serve.estimator as estimator_mod
from repro.core import CostModelConfig, GNNConfig, init_cost_model
from repro.core.graph import build_graph_skeleton, query_static
from repro.dsps import WorkloadGenerator
from repro.dsps.placement import Placement
from repro.placement import PlacementOptimizer, sample_assignment_matrix
from repro.serve.estimator import placed_predict

METRICS = ("latency_p", "success", "backpressure")


class BuildCounter:
    """Counts candidate-graph materializations in both build entry points."""

    def __init__(self):
        self.single = 0  # build_graph calls (one candidate each)
        self.batch = 0  # candidates materialized via build_graph_batch

    def install(self):
        self._orig_single = graph_mod.build_graph
        self._orig_batch = graph_mod.build_graph_batch
        self._orig_place = graph_mod.build_a_place_batch

        def counted_single(*a, **kw):
            self.single += 1
            return self._orig_single(*a, **kw)

        def counted_batch(query, cluster, assignments, *a, **kw):
            # no count here: build_graph_batch delegates to build_a_place_batch
            # (patched below), which counts the candidates exactly once
            return self._orig_batch(query, cluster, assignments, *a, **kw)

        def counted_place(query, cluster, assignments, *a, **kw):
            self.batch += len(np.asarray(assignments))
            return self._orig_place(query, cluster, assignments, *a, **kw)

        graph_mod.build_graph = counted_single
        graph_mod.build_graph_batch = counted_batch
        graph_mod.build_a_place_batch = counted_place
        # the optimizer/estimator imported the names directly; patch their
        # module globals too (scoring lives on the CostEstimator facade now)
        optimizer_mod.build_graph = counted_single
        estimator_mod.build_graph = counted_single
        estimator_mod.build_graph_batch = counted_batch
        estimator_mod.build_a_place_batch = counted_place
        return self

    def uninstall(self):
        graph_mod.build_graph = self._orig_single
        graph_mod.build_graph_batch = self._orig_batch
        graph_mod.build_a_place_batch = self._orig_place
        optimizer_mod.build_graph = self._orig_single
        estimator_mod.build_graph = self._orig_single
        estimator_mod.build_graph_batch = self._orig_batch
        estimator_mod.build_a_place_batch = self._orig_place

    @property
    def total(self) -> int:
        return self.single + self.batch

    def reset(self):
        self.single = self.batch = 0


def make_models(hidden: int = 32, n_ensemble: int = 3, use_pallas: bool = False):
    """Per-metric ensembles sharing WEIGHTS across pallas/jnp variants, so the
    kernel-routing comparison is apples-to-apples on identical params."""
    models = {}
    for i, metric in enumerate(METRICS):
        cfg = CostModelConfig(
            metric=metric,
            n_ensemble=n_ensemble,
            gnn=GNNConfig(hidden=hidden, use_pallas=use_pallas),
        )
        models[metric] = (init_cost_model(jax.random.PRNGKey(i), cfg), cfg)
    return models


def run(n_candidates: int, repeats: int, seed: int = 0) -> dict:
    repeats = max(1, repeats)
    gen = WorkloadGenerator(seed=seed)
    q = gen.query(kind="two_way", name="bench")
    c = gen.cluster(6)
    rng = np.random.default_rng(seed)
    a = sample_assignment_matrix(q, c, n_candidates, rng, max_tries_factor=200)
    if len(a) != n_candidates:
        raise SystemExit(f"only {len(a)}/{n_candidates} distinct candidates available")
    candidates = [Placement.of(row) for row in a]

    models_jnp = make_models()
    models_pal = make_models(use_pallas=True)
    opt = PlacementOptimizer(models_jnp)  # fused jnp (+ seed path)
    opt_pal = PlacementOptimizer(models_pal)  # fused + kernel-routed

    # the PR-1 path: skeleton hoisted, but one forward per (metric, member);
    # a_place built per call exactly like the optimizer's scoring closure
    skel = jax.tree_util.tree_map(jnp.asarray, build_graph_skeleton(q, c))
    static = query_static(q)

    def seed_path():
        return {m: opt.score_candidates(q, c, candidates, m) for m in METRICS}

    def unfused_path():
        a_place = jnp.asarray(graph_mod.build_a_place_batch(q, c, a))
        return {
            m: placed_predict(models_jnp[m][0], skel, a_place, static, models_jnp[m][1])
            for m in METRICS
        }

    def fused_path():
        return opt.score_assignments(q, c, a, METRICS)

    def fused_pallas_path():
        return opt_pal.score_assignments(q, c, a, METRICS)

    # warm up every jit cache at the benchmark's bucket shape, then verify all
    # paths agree before trusting the timings
    ref = seed_path()
    for name, path in (
        ("unfused", unfused_path),
        ("fused", fused_path),
        ("fused_pallas", fused_pallas_path),
    ):
        got = path()
        for m in METRICS:
            np.testing.assert_allclose(
                got[m], ref[m], rtol=1e-4, atol=1e-4, err_msg=f"{name}:{m}"
            )

    counter = BuildCounter().install()
    try:
        timings, builds = {}, {}
        for name, path in (
            ("seed", seed_path),
            ("unfused", unfused_path),
            ("fused", fused_path),
            ("fused_pallas", fused_pallas_path),
        ):
            counter.reset()
            t0 = time.perf_counter()
            for _ in range(repeats):
                path()
            timings[name] = (time.perf_counter() - t0) / repeats
            builds[name] = counter.total / repeats
    finally:
        counter.uninstall()

    rate = {name: n_candidates / t for name, t in timings.items()}
    return {
        "n_candidates": n_candidates,
        "n_metrics": len(METRICS),
        "repeats": repeats,
        "seed_path_s": round(timings["seed"], 4),
        "unfused_path_s": round(timings["unfused"], 4),
        "fused_path_s": round(timings["fused"], 4),
        "fused_pallas_path_s": round(timings["fused_pallas"], 4),
        "seed_cands_per_s": round(rate["seed"], 1),
        "unfused_cands_per_s": round(rate["unfused"], 1),
        "fused_cands_per_s": round(rate["fused"], 1),
        "fused_pallas_cands_per_s": round(rate["fused_pallas"], 1),
        # headline ratios: fusion win, kernel-routing win, end-to-end win
        "speedup_fused_vs_seed": round(timings["seed"] / timings["fused"], 2),
        "fused_vs_unfused": round(rate["fused"] / rate["unfused"], 3),
        "pallas_vs_jnp": round(rate["fused_pallas"] / rate["fused"], 3),
        "fused_pallas_vs_unfused": round(rate["fused_pallas"] / rate["unfused"], 3),
        "seed_builds_per_candidate": round(builds["seed"] / n_candidates, 2),
        "fast_builds_per_candidate": round(builds["fused"] / n_candidates, 2),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--candidates", type=int, default=1024)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--quick", action="store_true", help="small run for per-PR CI")
    ap.add_argument("--min-speedup", type=float, default=None, help="fail below this")
    ap.add_argument(
        "--baseline",
        type=str,
        default=None,
        help="JSON with recorded fused_vs_unfused / pallas_vs_jnp ratios",
    )
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.10,
        help="allowed fractional drop of a measured ratio below the baseline",
    )
    args = ap.parse_args(argv)
    if args.quick:
        args.candidates, args.repeats = 256, 3

    res = run(args.candidates, args.repeats)
    print(json.dumps(res, indent=2))
    # not assert: these are the CI gate's invariants, they must survive python -O
    if res["fast_builds_per_candidate"] != 1.0:
        raise SystemExit(
            "fast path must build each candidate graph exactly once, got "
            f"{res['fast_builds_per_candidate']}"
        )
    if args.min_speedup is not None and res["speedup_fused_vs_seed"] < args.min_speedup:
        raise SystemExit(
            f"scoring speedup {res['speedup_fused_vs_seed']}x below required "
            f"{args.min_speedup}x"
        )
    if args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
        for key in ("fused_vs_unfused", "pallas_vs_jnp"):
            floor = base[key] * (1.0 - args.max_regression)
            if res[key] < floor:
                raise SystemExit(
                    f"{key} ratio {res[key]} regressed >"
                    f"{args.max_regression:.0%} below recorded baseline "
                    f"{base[key]} (floor {floor:.3f})"
                )


if __name__ == "__main__":
    main()
