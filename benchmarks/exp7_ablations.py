"""[Exp 7] Ablations.

7a (Fig. 12): featurization — (1) operators only, (2) + placement structure
without hardware features, (3) full joint graph; L_e q-errors.
7b (Fig. 13): traditional symmetric message passing vs. the paper's 3-stage
scheme; regression q-errors.
"""

from __future__ import annotations

from benchmarks.common import eval_costream, fmt_table, save_result, test_split_traces
from repro.core import REGRESSION_METRICS
from repro.core.graph import drop_hardware, drop_hw_features


def exp7a():
    traces = test_split_traces()
    from repro.launch import artifacts as A

    # equal-budget "full" model if it exists, else the main 20-epoch model
    full_prefix = "ablate_full" if A.exists("costream", "ablate_full_latency_e") else "main"
    variants = [
        ("ops only (no hw nodes)", "ablate_no_hw_nodes", drop_hardware),
        ("+ placement, no hw feats", "ablate_no_hw_feats", drop_hw_features),
        ("full featurization", full_prefix, None),
    ]
    rows = []
    for label, prefix, transform in variants:
        r = eval_costream(traces, metrics=("latency_e",), prefix=prefix, transform=transform)
        rows.append(
            {
                "featurization": label,
                "Le_q50": round(r["latency_e"].get("q50", float("nan")), 2),
                "Le_q95": round(r["latency_e"].get("q95", float("nan")), 2),
            }
        )
    print("\n[Exp 7a / Fig 12] featurization ablation (L_e)")
    print(fmt_table(rows, ["featurization", "Le_q50", "Le_q95"]))
    save_result("exp7a_fig12", rows)
    return rows


def exp7b():
    traces = test_split_traces()
    rows = []
    for m in REGRESSION_METRICS:
        ours = eval_costream(traces, metrics=(m,), prefix="main")
        trad = eval_costream(traces, metrics=(m,), prefix="ablate_traditional")
        rows.append(
            {
                "metric": m,
                "ours_q50": round(ours[m].get("q50", float("nan")), 2),
                "traditional_q50": round(trad[m].get("q50", float("nan")), 2),
            }
        )
    print("\n[Exp 7b / Fig 13] message-passing scheme ablation")
    print(fmt_table(rows, ["metric", "ours_q50", "traditional_q50"]))
    save_result("exp7b_fig13", rows)
    return rows


def main():
    exp7a()
    exp7b()


if __name__ == "__main__":
    main()
