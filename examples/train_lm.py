"""LM training driver: any assigned architecture, synthetic token stream,
atomic checkpointing with restart, optional failure injection.

Default is a fast reduced config; ``--scale full --arch xlstm-125m`` trains
the real 125M config (slow on 1 CPU core — sized for TPU).

    PYTHONPATH=src python examples/train_lm.py --arch xlstm-125m --steps 60
    PYTHONPATH=src python examples/train_lm.py --arch gemma2-2b --inject-failure 20
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models.params import count_params, materialize
from repro.models.steps import TrainStepConfig, make_train_step
from repro.models.transformer import model_defs
from repro.training.checkpoint import restore_checkpoint, save_checkpoint


def synthetic_batch(cfg, B, S, step):
    rng = np.random.default_rng(step)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.frontend == "vision":
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S - cfg.vis_len)), jnp.int32),
            "vis_embeds": jnp.asarray(rng.normal(size=(B, cfg.vis_len, cfg.d_model)) * 0.02, jnp.float32),
        }
    if cfg.frontend == "audio":
        batch["frames"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)) * 0.02, jnp.float32)
    return batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--scale", default="reduced", choices=["reduced", "full"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--inject-failure", type=int, default=0,
                    help="simulate a crash at this step, then auto-restart")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.scale == "reduced":
        cfg = reduced(cfg)
    print(f"arch={cfg.name} params={count_params(model_defs(cfg)) / 1e6:.1f}M "
          f"layers={cfg.n_layers()}")

    train_step, opt = make_train_step(cfg, TrainStepConfig(lr=1e-3))
    params = materialize(jax.random.PRNGKey(0), model_defs(cfg), dtype_override=jnp.float32)
    state = {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}

    # fault tolerance: resume from the newest atomic checkpoint if present
    restored, step0, _ = restore_checkpoint(args.ckpt_dir, state)
    if restored is not None:
        state = jax.tree_util.tree_map(jnp.asarray, restored)
        print(f"resumed from checkpoint at step {step0}")
    start = int(state["step"])

    jit_step = jax.jit(train_step, donate_argnums=(0,))
    t0 = time.time()
    for step in range(start, args.steps):
        batch = synthetic_batch(cfg, args.batch, args.seq, step)
        state, metrics = jit_step(state, batch)
        if args.inject_failure and step == args.inject_failure:
            print(f"!! injected failure at step {step} — restart this script to resume")
            raise SystemExit(17)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time() - t0):.1f}s)")
        if step > 0 and step % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step,
                            jax.tree_util.tree_map(np.asarray, state))
            print(f"checkpointed step {step}")
    save_checkpoint(args.ckpt_dir, args.steps, jax.tree_util.tree_map(np.asarray, state))
    print("done; final checkpoint saved")


if __name__ == "__main__":
    main()
