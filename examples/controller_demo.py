"""Closed-loop demo: a query fleet survives drift and a node failure.

Builds the benchmark's weak edge cluster, places a small fleet with the
contention-aware greedy planner, then replays a seeded scenario — an x8
event-rate drift on two queries and the failure of the strongest host — with
a ``PlacementController`` watching fleet telemetry (docs/controller.md).
A do-nothing static run of the SAME scenario shows what the controller is
worth.  Uses the noise-free simulator oracle as the scorer, so the demo
needs no trained checkpoint; swap ``scorer=`` for ``estimator=`` to drive
it with a trained ``CostEstimator``.

    PYTHONPATH=src python examples/controller_demo.py [--smoke]

``--smoke`` shrinks fleet/ticks to CI scale (scripts/ci.sh runs it so API
drift in this example fails the gate instead of rotting silently).
"""

import argparse

from repro.control import (
    FleetRuntime,
    PlacementController,
    SimulatorScorer,
    build_scenario,
    run_static,
)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny fleet/ticks for CI")
    args = ap.parse_args(argv)
    n_queries = 4 if args.smoke else 6
    n_ticks = 12 if args.smoke else 20

    fleet, cluster, events = build_scenario(n_queries, n_ticks)
    print(f"fleet of {n_queries} queries on {cluster.n_nodes()} hosts; scenario:")
    for ev in events:
        if ev.kind == "join":
            tgt = f"node(cpu={ev.node.cpu:.0f})"
        elif ev.query is not None:
            tgt = f"query {ev.query}"
        else:
            tgt = f"host {ev.host}"
        print(f"  tick {ev.tick:2d}: {ev.kind} {tgt}"
              + (f" x{ev.factor}" if ev.kind.endswith("drift") else ""))

    ctl = PlacementController(
        FleetRuntime(fleet, cluster, events, seed=1),
        scorer=SimulatorScorer(),
        seed=0,
    )
    print(f"\n{'tick':>4} {'fleet cost [ms]':>16}  events")
    for _ in range(n_ticks):
        rec = ctl.step()
        notes = [f"{a.kind}(q{a.query_id})" for a in rec.alarms]
        notes += [
            f"{d.action}(q{d.query_id}"
            + (f": {list(d.old)}->{list(d.new)}, {d.migration_mb:.3f}MB)" if d.action == "migrate" else ")")
            for d in rec.decisions
        ]
        print(f"{rec.tick:>4} {rec.fleet_cost_ms:>16.1f}  {' '.join(notes)}")

    rep = ctl.report()
    static = run_static(FleetRuntime(fleet, cluster, events, seed=1), n_ticks)
    print(f"\ncontroller: final {rep.final_cost_ms:10.1f} ms, "
          f"{rep.n_migrations} migrations ({rep.migrated_mb:.3f} MB), "
          f"replan p95 {rep.replan_p95_ms:.1f} ms over {rep.n_replans} rounds")
    print(f"static    : final {static.final_cost_ms:10.1f} ms, 0 migrations")
    ratio = static.final_cost_ms / max(rep.final_cost_ms, 1e-9)
    print(f"end-of-run fleet cost ratio (static/controller): {ratio:.1f}x")
    if ratio <= 1.0:
        raise SystemExit("controller failed to beat the static baseline")


if __name__ == "__main__":
    main()
