"""Batched decode serving: KV-cached single-token steps over a request batch.

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b --tokens 12
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models.params import count_params, materialize
from repro.models.steps import make_serve_step
from repro.models.transformer import model_cache_defs, model_defs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    print(f"serving {cfg.name} (reduced, {count_params(model_defs(cfg)) / 1e6:.1f}M params), "
          f"batch={args.batch}, cache={args.max_seq}")

    params = materialize(jax.random.PRNGKey(0), model_defs(cfg), dtype_override=jnp.float32)
    cache = materialize(jax.random.PRNGKey(1), model_cache_defs(cfg, args.batch, args.max_seq))
    cache = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x, cache
    )
    serve_step = jax.jit(make_serve_step(cfg))

    # prompt: one BOS-ish token per request
    toks = jnp.ones((args.batch, 1), jnp.int32)
    out = [toks]
    t0 = time.time()
    for i in range(args.tokens):
        logits, cache, toks = serve_step(params, cache, toks, jnp.asarray(i, jnp.int32))
        out.append(toks)
    dt = time.time() - t0
    seqs = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"decoded {args.tokens} tokens x {args.batch} requests in {dt:.2f}s "
          f"({args.tokens * args.batch / dt:.1f} tok/s)")
    for b in range(args.batch):
        print(f"  request {b}: {seqs[b].tolist()}")


if __name__ == "__main__":
    main()
