"""Quickstart: generate a workload corpus, train a COSTREAM latency model,
save it as a versioned CostModelBundle, and serve predictions for unseen
placed queries through the CostEstimator facade.

    PYTHONPATH=src python examples/quickstart.py [--smoke]

``--smoke`` shrinks corpus/epochs to CI scale (scripts/ci.sh runs it so API
drift in this example fails the gate instead of rotting silently).
"""

import argparse
import os
import tempfile

from repro import CostEstimator, CostModelBundle, CostModelConfig, WorkloadGenerator
from repro.core import GNNConfig, qerror_summary
from repro.training import TrainConfig, dataset_from_traces, split_dataset, train_cost_model


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny corpus/epochs for CI")
    ap.add_argument("--corpus", type=int, default=None)
    ap.add_argument("--epochs", type=int, default=None)
    args = ap.parse_args(argv)
    n_corpus = args.corpus or (160 if args.smoke else 1500)
    epochs = args.epochs or (2 if args.smoke else 10)
    hidden = 24 if args.smoke else 48

    # 1. benchmark corpus (paper SVI): random queries x hardware x placements,
    #    labeled by the DSPS cost simulator
    gen = WorkloadGenerator(seed=0)
    traces = gen.corpus(n_corpus)
    print(f"corpus: {len(traces)} traces, "
          f"{sum(t.labels.backpressure == 0 for t in traces)} backpressured, "
          f"{sum(t.labels.success == 0 for t in traces)} failed")

    # 2. train a processing-latency cost model (ensemble of 2 for speed)
    ds = dataset_from_traces(traces, "latency_p")
    train, val, test = split_dataset(ds)
    cfg = CostModelConfig(metric="latency_p", n_ensemble=2, gnn=GNNConfig(hidden=hidden))
    result = train_cost_model(
        train, val, cfg, TrainConfig(epochs=epochs, batch_size=256, verbose=not args.smoke)
    )

    # 3. package the trained ensemble as the ONE versioned serving artifact
    #    and round-trip it through disk — exactly what a deployment loads
    bundle = CostModelBundle(
        models={"latency_p": (result.params, cfg)},
        meta={"corpus": n_corpus, "epochs": epochs, "best_val": result.best_val},
    )
    # load() is lazy by default (params deserialize on first use), so the
    # bundle directory must outlive the estimator serving from it — keep the
    # tempdir open for the whole serving session below
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "latency_bundle")
        bundle.save(path)
        served = CostModelBundle.load(path)
        print(f"bundle round-trip: metrics={served.metrics} meta={served.meta}")
        serve_session(served, gen, test)


def serve_session(served, gen, test):
    # 4. zero-shot predictions on unseen placed queries via the facade
    est = CostEstimator.from_bundle(served)
    pred = est.estimate(test.graphs, metrics=["latency_p"])["latency_p"]
    print("\nq-error on held-out queries:", qerror_summary(test.labels, pred))
    for i in range(3):
        print(f"  query {i}: true {test.labels[i]:9.1f} ms   predicted {pred[i]:9.1f} ms")

    # 5. serving a heterogeneous stream: many DISTINCT small queries arrive
    #    concurrently, each scoring a couple of candidate placements.  The
    #    PlacementService groups score requests per metrics tuple and answers
    #    a whole dispatch-bound drain with ONE merged cross-query forward
    #    (docs/forward_engine.md#merged) instead of one per structure.
    import numpy as np

    from repro import PlacementService
    from repro.placement import sample_assignment_matrix

    rng = np.random.default_rng(7)
    stream = []
    for i, kind in enumerate(["linear", "two_way", "three_way", "linear"] * 2):
        q = gen.query(kind=kind, name=f"stream{i}")
        c = gen.cluster(3 + i % 5)
        stream.append((q, c, sample_assignment_matrix(q, c, 2, rng)))
    svc = PlacementService(est, auto_start=False)  # queue first: one drain
    futures = [svc.submit_score(q, c, a, ["latency_p"]) for q, c, a in stream]
    svc.start()
    answers = [f.result() for f in futures]
    svc.close()
    print(f"\nheterogeneous stream: {len(stream)} distinct queries answered by "
          f"{svc.stats.n_forwards} fused forward(s) "
          f"({svc.stats.n_cross_query} cross-query coalesced)")
    for i in (0, 1):
        best = answers[i]["latency_p"].argmin()
        print(f"  {stream[i][0].name}: best of {len(answers[i]['latency_p'])} "
              f"candidates predicts {answers[i]['latency_p'][best]:9.1f} ms")


if __name__ == "__main__":
    main()
