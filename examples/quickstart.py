"""Quickstart: generate a workload corpus, train a COSTREAM latency model,
and predict the cost of an unseen placed query.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CostModelConfig, GNNConfig, predict, qerror_summary
from repro.dsps import WorkloadGenerator
from repro.training import TrainConfig, dataset_from_traces, split_dataset, train_cost_model


def main():
    # 1. benchmark corpus (paper SVI): random queries x hardware x placements,
    #    labeled by the DSPS cost simulator
    gen = WorkloadGenerator(seed=0)
    traces = gen.corpus(1500)
    print(f"corpus: {len(traces)} traces, "
          f"{sum(t.labels.backpressure == 0 for t in traces)} backpressured, "
          f"{sum(t.labels.success == 0 for t in traces)} failed")

    # 2. train a processing-latency cost model (ensemble of 2 for speed)
    ds = dataset_from_traces(traces, "latency_p")
    train, val, test = split_dataset(ds)
    cfg = CostModelConfig(metric="latency_p", n_ensemble=2, gnn=GNNConfig(hidden=48))
    result = train_cost_model(
        train, val, cfg, TrainConfig(epochs=10, batch_size=256, verbose=True)
    )

    # 3. zero-shot predictions on unseen placed queries
    g = jax.tree_util.tree_map(jnp.asarray, test.graphs)
    pred = predict(result.params, g, cfg)
    print("\nq-error on held-out queries:", qerror_summary(test.labels, pred))
    for i in range(3):
        print(f"  query {i}: true {test.labels[i]:9.1f} ms   predicted {pred[i]:9.1f} ms")


if __name__ == "__main__":
    main()
