"""Quickstart: generate a workload corpus, train a COSTREAM latency model,
save it as a versioned CostModelBundle, and serve predictions for unseen
placed queries through the CostEstimator facade.

    PYTHONPATH=src python examples/quickstart.py [--smoke]

``--smoke`` shrinks corpus/epochs to CI scale (scripts/ci.sh runs it so API
drift in this example fails the gate instead of rotting silently).
"""

import argparse
import os
import tempfile

from repro import CostEstimator, CostModelBundle, CostModelConfig, WorkloadGenerator
from repro.core import GNNConfig, qerror_summary
from repro.training import TrainConfig, dataset_from_traces, split_dataset, train_cost_model


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny corpus/epochs for CI")
    ap.add_argument("--corpus", type=int, default=None)
    ap.add_argument("--epochs", type=int, default=None)
    args = ap.parse_args(argv)
    n_corpus = args.corpus or (160 if args.smoke else 1500)
    epochs = args.epochs or (2 if args.smoke else 10)
    hidden = 24 if args.smoke else 48

    # 1. benchmark corpus (paper SVI): random queries x hardware x placements,
    #    labeled by the DSPS cost simulator
    gen = WorkloadGenerator(seed=0)
    traces = gen.corpus(n_corpus)
    print(f"corpus: {len(traces)} traces, "
          f"{sum(t.labels.backpressure == 0 for t in traces)} backpressured, "
          f"{sum(t.labels.success == 0 for t in traces)} failed")

    # 2. train a processing-latency cost model (ensemble of 2 for speed)
    ds = dataset_from_traces(traces, "latency_p")
    train, val, test = split_dataset(ds)
    cfg = CostModelConfig(metric="latency_p", n_ensemble=2, gnn=GNNConfig(hidden=hidden))
    result = train_cost_model(
        train, val, cfg, TrainConfig(epochs=epochs, batch_size=256, verbose=not args.smoke)
    )

    # 3. package the trained ensemble as the ONE versioned serving artifact
    #    and round-trip it through disk — exactly what a deployment loads
    bundle = CostModelBundle(
        models={"latency_p": (result.params, cfg)},
        meta={"corpus": n_corpus, "epochs": epochs, "best_val": result.best_val},
    )
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "latency_bundle")
        bundle.save(path)
        served = CostModelBundle.load(path)
    print(f"bundle round-trip: metrics={served.metrics} meta={served.meta}")

    # 4. zero-shot predictions on unseen placed queries via the facade
    est = CostEstimator.from_bundle(served)
    pred = est.estimate(test.graphs, metrics=["latency_p"])["latency_p"]
    print("\nq-error on held-out queries:", qerror_summary(test.labels, pred))
    for i in range(3):
        print(f"  query {i}: true {test.labels[i]:9.1f} ms   predicted {pred[i]:9.1f} ms")


if __name__ == "__main__":
    main()
