"""End-to-end driver for the paper's use case: cost-based INITIAL operator
placement (paper SV, Fig. 4).

Trains small per-metric ensembles, bundles them, then for a set of streaming
queries runs heuristic placement [32] vs. COSTREAM-optimized placement
through the CostEstimator facade, with the simulator as ground truth.
Reports the measured L_p speedups.

    PYTHONPATH=src python examples/optimize_placement.py [--smoke]

``--smoke`` shrinks corpus/epochs/queries to CI scale (scripts/ci.sh runs it
so API drift in this example fails the gate instead of rotting silently).
"""

import argparse
import time

import numpy as np

from repro import CostEstimator, CostModelBundle, CostModelConfig, WorkloadGenerator
from repro.core import GNNConfig
from repro.dsps import simulate
from repro.dsps.simulator import SimulatorConfig
from repro.placement import heuristic_placement
from repro.training import TrainConfig, dataset_from_traces, split_dataset, train_cost_model

SIM = SimulatorConfig(noise_sigma=0.0)


def train_bundle(traces, epochs: int, hidden: int) -> CostModelBundle:
    models = {}
    for metric in ("latency_p", "success", "backpressure"):
        ds = dataset_from_traces(traces, metric)
        tr, va, _ = split_dataset(ds)
        cfg = CostModelConfig(metric=metric, n_ensemble=3, gnn=GNNConfig(hidden=hidden))
        res = train_cost_model(tr, va, cfg, TrainConfig(epochs=epochs, batch_size=256))
        models[metric] = (res.params, cfg)
        print(f"trained {metric}: best val loss {res.best_val:.4f}")
    return CostModelBundle(models, meta={"epochs": epochs, "corpus": len(traces)})


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny corpus/epochs for CI")
    args = ap.parse_args(argv)
    n_corpus = 300 if args.smoke else 2000
    epochs = 2 if args.smoke else 8
    n_queries = 2 if args.smoke else 10
    k = 16 if args.smoke else 48
    refine = 1 if args.smoke else 2

    gen = WorkloadGenerator(seed=1)
    print("generating training corpus...")
    bundle = train_bundle(gen.corpus(n_corpus), epochs, hidden=32 if args.smoke else 48)
    estimator = CostEstimator.from_bundle(bundle)

    rng = np.random.default_rng(0)
    speedups = []
    scored = 0
    t0 = time.perf_counter()
    for i in range(n_queries):
        q = gen.query(name=f"demo{i}")
        cluster = gen.cluster(6)
        base = heuristic_placement(q, cluster)
        base_lat = simulate(q, cluster, base, SIM).latency_p

        # vectorized sample -> batched multi-metric scoring -> hill-climb
        # refinement of the top candidates (docs/placement_search.md), all
        # behind the facade's one-call search entry point
        res = estimator.optimize(q, cluster, "latency_p", k=k, rng=rng, refine_rounds=refine)
        scored += res.n_candidates
        opt_lat = simulate(q, cluster, res.placement, SIM).latency_p
        speedups.append(base_lat / max(opt_lat, 1e-9))
        print(
            f"query {i} ({q.n_ops()} ops): heuristic {base_lat:9.1f} ms -> "
            f"costream {opt_lat:9.1f} ms   speedup {speedups[-1]:6.2f}x "
            f"({res.n_feasible}/{res.n_candidates} feasible candidates)"
        )
    dt = time.perf_counter() - t0
    print(f"\nmedian speedup: {np.median(speedups):.2f}x")
    # wall clock includes per-query jit warmup and the simulator ground-truth
    # runs; see benchmarks/placement_bench.py for steady-state scoring rates
    print(f"end-to-end: {scored / dt:.0f} candidates scored/s (x3 metrics, incl. compile+sim)")


if __name__ == "__main__":
    main()
