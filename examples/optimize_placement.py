"""End-to-end driver for the paper's use case: cost-based INITIAL operator
placement (paper SV, Fig. 4).

Trains small per-metric ensembles, then for a set of streaming queries:
heuristic placement [32] vs. COSTREAM-optimized placement, with the
simulator as ground truth. Reports the measured L_p speedups.

    PYTHONPATH=src python examples/optimize_placement.py
"""

import jax
import numpy as np

from repro.core import CostModelConfig, GNNConfig
from repro.dsps import WorkloadGenerator, simulate
from repro.dsps.simulator import SimulatorConfig
from repro.placement import PlacementOptimizer, heuristic_placement
from repro.training import TrainConfig, dataset_from_traces, split_dataset, train_cost_model

SIM = SimulatorConfig(noise_sigma=0.0)


def train_models(traces):
    models = {}
    for metric in ("latency_p", "success", "backpressure"):
        ds = dataset_from_traces(traces, metric)
        tr, va, _ = split_dataset(ds)
        cfg = CostModelConfig(metric=metric, n_ensemble=3, gnn=GNNConfig(hidden=48))
        res = train_cost_model(tr, va, cfg, TrainConfig(epochs=8, batch_size=256))
        models[metric] = (res.params, cfg)
        print(f"trained {metric}: best val loss {res.best_val:.4f}")
    return models


def main():
    import time

    gen = WorkloadGenerator(seed=1)
    print("generating training corpus...")
    models = train_models(gen.corpus(2000))
    optimizer = PlacementOptimizer(models)

    rng = np.random.default_rng(0)
    speedups = []
    scored = 0
    t0 = time.perf_counter()
    for i in range(10):
        q = gen.query(name=f"demo{i}")
        cluster = gen.cluster(6)
        base = heuristic_placement(q, cluster)
        base_lat = simulate(q, cluster, base, SIM).latency_p

        # vectorized sample -> batched multi-metric scoring -> hill-climb
        # refinement of the top candidates (docs/placement_search.md)
        res = optimizer.optimize(q, cluster, "latency_p", k=48, rng=rng, refine_rounds=2)
        scored += res.n_candidates
        opt_lat = simulate(q, cluster, res.placement, SIM).latency_p
        speedups.append(base_lat / max(opt_lat, 1e-9))
        print(
            f"query {i} ({q.n_ops()} ops): heuristic {base_lat:9.1f} ms -> "
            f"costream {opt_lat:9.1f} ms   speedup {speedups[-1]:6.2f}x "
            f"({res.n_feasible}/{res.n_candidates} feasible candidates)"
        )
    dt = time.perf_counter() - t0
    print(f"\nmedian speedup: {np.median(speedups):.2f}x")
    # wall clock includes per-query jit warmup and the simulator ground-truth
    # runs; see benchmarks/placement_bench.py for steady-state scoring rates
    print(f"end-to-end: {scored / dt:.0f} candidates scored/s (x3 metrics, incl. compile+sim)")


if __name__ == "__main__":
    main()
